"""ATA prefix-cache tests: paper Table-I invariants in the serving
domain + hash/property checks."""
import numpy as np
import pytest

from repro.serving import (AtaCacheConfig, AtaPrefixCache, POLICIES,
                           run_workload, synth_requests)

CFG = AtaCacheConfig(n_shards=8)


@pytest.fixture(scope="module")
def shared_stats():
    # 300+ requests: past the cold-start transient, so steady-state
    # replication behavior (paper Fig. 7a) is observable
    reqs = synth_requests(300, n_shards=8, shared_frac=0.75, seed=3)
    return {p: run_workload(p, CFG, reqs) for p in POLICIES}


def test_sharing_beats_private_hit_rate(shared_stats):
    s = shared_stats
    for pol in ("remote", "decoupled", "ata"):
        assert s[pol].hit_rate > s["private"].hit_rate + 0.05, pol


def test_ata_zero_probe_messages(shared_stats):
    assert shared_stats["ata"].probe_messages == 0
    assert shared_stats["remote"].probe_messages > 1000


def test_ata_matches_remote_sharing_hit_rate(shared_stats):
    # same replicated-visibility semantics, without the probe traffic
    assert abs(shared_stats["ata"].hit_rate
               - shared_stats["remote"].hit_rate) < 0.02


def test_ata_serves_mostly_local_after_warmup(shared_stats):
    """Paper Fig. 7(a): remote fetches fill the local cache, so hot
    blocks replicate and service becomes mostly local."""
    s = shared_stats["ata"]
    assert s.local_hits > s.remote_hits
    dec = shared_stats["decoupled"]
    assert dec.local_hits < dec.remote_hits   # decoupled cannot replicate


def test_ata_remote_traffic_below_decoupled(shared_stats):
    assert (shared_stats["ata"].remote_fetch_blocks
            < 0.75 * shared_stats["decoupled"].remote_fetch_blocks)


def test_low_locality_no_ata_penalty():
    reqs = synth_requests(150, n_shards=8, shared_frac=0.05, seed=4)
    s_priv = run_workload("private", CFG, reqs)
    s_ata = run_workload("ata", CFG, reqs)
    assert s_ata.hit_rate >= s_priv.hit_rate - 1e-9
    assert s_ata.probe_messages == 0


def test_directory_local_write_rule():
    """New blocks are sealed only into the requesting shard's pool."""
    cache = AtaPrefixCache(CFG, "ata")
    toks = np.arange(64)
    cache.lookup_prefix(3, toks)
    for s in range(CFG.n_shards):
        n = len(cache.pool_payload[s])
        assert (n > 0) == (s == 3)


def test_kernel_backed_directory_probe_agrees():
    """The serving directory's parallel compare == ata_tag_probe kernel."""
    import jax.numpy as jnp
    from repro.kernels import ops
    cache = AtaPrefixCache(AtaCacheConfig(n_shards=4, n_sets=8, n_ways=4),
                           "ata")
    rng = np.random.default_rng(0)
    for _ in range(30):
        cache.insert(int(rng.integers(4)), int(rng.integers(1, 2**31)),
                     "blk")
    hashes = np.asarray([int(h) for h in
                         rng.integers(1, 2**31, 64)], np.int64)
    # plant some known entries
    for i in range(0, 64, 5):
        cache.insert(i % 4, int(hashes[i]), "blk")
    hit_ref, _ = cache.probe(0, hashes, "all")
    set_idx = (hashes % cache.cfg.n_sets).astype(np.int32)
    h32 = (hashes % (2**31)).astype(np.int32)
    tags32 = (cache.tags % (2**31)).astype(np.int32)
    hits, _ = ops.ata_probe(jnp.asarray(set_idx), jnp.asarray(h32),
                            jnp.asarray(tags32),
                            jnp.asarray(cache.valid), impl="interpret",
                            br=64, bc=4)
    np.testing.assert_array_equal(np.asarray(hits).any(axis=1), hit_ref)
