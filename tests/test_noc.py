"""NoC subsystem tests: registry, ideal bit-exactness (goldens + the
committed sensitivity baseline), flit conservation for every registered
model, topology behavior (crossbar backpressure, ring hop latency),
sweep-grid stacking/executable accounting, and the report's ``noc``
section + regression gate."""
import dataclasses
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (APPS, PAPER_GEOMETRY, PAPER_NOCS, SweepGrid,
                        SweepPoint, get_noc, make_trace, register_noc,
                        registered_nocs, simulate)
from repro.core import report as sensitivity
from repro.core.noc import NocModel, NocTraffic, init_noc_state
from repro.core.noc.base import port_rate

ROOT = os.path.join(os.path.dirname(__file__), "..")
BASELINE = os.path.join(ROOT, "benchmarks", "baselines",
                        "sensitivity_rounds96.json")


def _trace(app="cfd", rounds=96, kernel=1):
    return make_trace(dataclasses.replace(APPS[app], rounds=rounds),
                      kernel=kernel)


def same_result(a, b):
    return all(x == y or (x != x and y != y)
               for x, y in zip(tuple(a), tuple(b)))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_contains_builtin_models_in_order():
    assert registered_nocs() == PAPER_NOCS == ("ideal", "crossbar", "ring")
    # the built-ins share one stacking family by construction
    assert {get_noc(n).stack_key for n in PAPER_NOCS} == {"noc"}


def test_register_noc_rejects_duplicates_and_non_models():
    from repro.core.noc import IdealNoc
    with pytest.raises(ValueError, match="already registered"):
        register_noc(IdealNoc())
    with pytest.raises(TypeError):
        register_noc("ideal")  # type: ignore[arg-type]
    with pytest.raises(ValueError, match="unknown NoC model"):
        get_noc("no_such_noc")
    with pytest.raises(ValueError, match="noc must be one of"):
        simulate("ata", _trace(rounds=8), noc="no_such_noc")


# ---------------------------------------------------------------------------
# ideal == the pre-NoC simulator, bit for bit
# ---------------------------------------------------------------------------
def test_ideal_is_the_default_and_reports_zero_noc_block():
    tr = _trace()
    base = simulate("ata", tr)
    explicit = simulate("ata", tr, noc="ideal")
    assert same_result(base, explicit)
    nb = base.noc
    assert nb.flits_injected == nb.flits_delivered > 0
    assert nb.flits_queued == 0.0 and nb.conserved
    assert nb.mean_queue_delay == nb.max_link_util == 0.0


def test_ideal_bit_exact_inside_stacked_noc_grid():
    """ideal points of a {ideal, crossbar, ring} grid — where the
    carried NoC state is sized for the whole model group — must match
    the solo (zero-sized state) simulate() exactly."""
    traces = [_trace(rounds=96)]
    grid = SweepGrid(("private", "remote", "ata"), None, traces,
                     nocs=PAPER_NOCS)
    run = grid.run()
    for pt, r in zip(grid.points, run.results):
        if pt.noc == "ideal":
            assert same_result(r, simulate(pt.arch, pt.trace, pt.geom)), \
                pt.arch


def test_ideal_bit_exact_with_committed_sensitivity_baseline():
    """Golden: the pre-NoC simulator's committed baseline cells are
    reproduced exactly with the NoC stage in place (noc='ideal' is the
    default everywhere the report runs)."""
    with open(BASELINE) as f:
        base = json.load(f)
    cfg = base["config"]
    knobs = {"noc_bw": tuple(cfg["knobs"]["noc_bw"])}
    rep = sensitivity.run_sensitivity(
        app=cfg["app"], archs=tuple(cfg["archs"]), knobs=knobs,
        kernels_per_app=cfg["kernels_per_app"], rounds=cfg["rounds"])
    want = {(c["arch"], c["knob"], c["value"]): c for c in base["cells"]}
    got = {(c["arch"], c["knob"], c["value"]): c for c in rep["cells"]}
    assert set(got) <= set(want)
    assert len(got) == len(cfg["archs"]) * len(knobs["noc_bw"])
    for key, cell in got.items():
        for metric in sensitivity.CELL_METRICS:
            np.testing.assert_allclose(
                cell[metric], want[key][metric], rtol=1e-6,
                err_msg=f"{key}/{metric}")


# ---------------------------------------------------------------------------
# flit conservation: injected == delivered + queued, per round + at end
# ---------------------------------------------------------------------------
def _random_traffic(rng, geom, R=64):
    core = rng.integers(0, geom.n_cores, R).astype(np.int32)
    cluster = core // geom.cluster_size
    peer = (cluster * geom.cluster_size
            + rng.integers(0, geom.cluster_size, R)).astype(np.int32)
    flits = (rng.integers(0, 3, R) * geom.flits_per_line).astype(np.float32)
    return NocTraffic(src=jnp.asarray(peer), dst=jnp.asarray(core),
                      cluster=jnp.asarray(cluster),
                      flits=jnp.asarray(flits),
                      mask=jnp.asarray(flits > 0))


@pytest.mark.parametrize("name", ("ideal", "crossbar", "ring"))
def test_flit_conservation_per_round(name):
    """Direct transit loop: the invariant holds after *every* round,
    including while a crossbar queue is draining a backlog."""
    model = get_noc(name)
    # tiny bandwidth so the crossbar actually queues across rounds
    geom = dataclasses.replace(PAPER_GEOMETRY, noc_bw=2.0, noc_drain=4.0)
    state = init_noc_state(model.n_links(geom))
    rng = np.random.default_rng(0)
    queued_seen = 0.0
    for t in range(24):
        traffic = _random_traffic(rng, geom) if t < 16 else \
            _random_traffic(rng, geom)._replace(
                flits=jnp.zeros(64, jnp.float32),
                mask=jnp.zeros(64, bool))       # drain-only rounds
        out = model.transit(geom, state, traffic)
        state = out.state
        injected = float(state["injected"])
        delivered = float(state["delivered"])
        queued = float(np.asarray(state["queue"]).sum())
        # exact up to f32 accumulation at non-representable drain rates
        assert injected == pytest.approx(delivered + queued,
                                         rel=1e-5, abs=1e-3), t
        assert (np.asarray(out.delay) >= 0).all()
        assert (np.asarray(out.occupancy) >= 0).all()
        queued_seen = max(queued_seen, queued)
    if name == "crossbar":
        assert queued_seen > 0.0      # backpressure actually engaged


@pytest.mark.parametrize("name", ("ideal", "crossbar", "ring"))
@pytest.mark.parametrize("arch", ("remote", "ata"))
def test_flit_conservation_end_of_sim(name, arch):
    geom = dataclasses.replace(PAPER_GEOMETRY, noc_bw=4.0)
    r = simulate(arch, _trace(rounds=96), geom, noc=name)
    nb = r.noc
    assert nb.flits_injected > 0
    assert nb.conserved
    assert nb.flits_injected == pytest.approx(
        nb.flits_delivered + nb.flits_queued, rel=1e-5, abs=1e-3)


# ---------------------------------------------------------------------------
# topology behavior
# ---------------------------------------------------------------------------
def test_crossbar_backpressure_monotone_in_noc_bw():
    tr = _trace()
    ipcs = [simulate("ata", tr,
                     dataclasses.replace(PAPER_GEOMETRY, noc_bw=bw),
                     noc="crossbar").ipc
            for bw in (2.0, 4.0, 16.0)]
    assert ipcs[0] < ipcs[1] <= ipcs[2]
    assert ipcs[2] <= simulate("ata", tr).ipc    # ideal is an upper bound


def test_crossbar_queue_carries_across_rounds():
    geom = dataclasses.replace(PAPER_GEOMETRY, noc_bw=2.0, noc_drain=4.0)
    r = simulate("remote", _trace(rounds=96), geom, noc="crossbar")
    # the probe-broadcast baseline overwhelms a 0.2 flit/cycle port:
    # a standing backlog must be visible at end-of-sim
    assert r.noc.flits_queued > 0
    assert r.noc.mean_queue_delay > 0


def test_ring_hop_latency_and_hotspots():
    tr = _trace()
    ideal = simulate("ata", tr)
    ring = simulate("ata", tr, noc="ring")
    assert ring.ipc <= ideal.ipc
    assert ring.noc.mean_queue_delay > 0          # hop latency
    assert ring.noc.max_link_util > 0             # per-link accounting
    # hop latency scales with ring_hop
    slow = simulate("ata", tr,
                    dataclasses.replace(PAPER_GEOMETRY, ring_hop=16.0),
                    noc="ring")
    assert slow.noc.mean_queue_delay > ring.noc.mean_queue_delay
    assert slow.ipc <= ring.ipc
    # hit/traffic counters are timing-independent: only timing moved
    assert ring.l1_hit_rate == ideal.l1_hit_rate
    assert ring.noc_flits == ideal.noc_flits


# ---------------------------------------------------------------------------
# sweep grid: stacking, executable accounting, bit-exactness
# ---------------------------------------------------------------------------
def test_acceptance_grid_stacks_within_executable_budget():
    """The ISSUE-5 acceptance grid: (4 archs x 3 nocs x scalar
    geometries) compiles <= 4 executables (actually 2: one per arch
    family — the NoC axis stacks), bit-identical to per-point
    simulate(..., noc=...)."""
    traces = [_trace(rounds=48)]
    geoms = [PAPER_GEOMETRY,
             dataclasses.replace(PAPER_GEOMETRY, noc_bw=4.0)]
    grid = SweepGrid(("private", "ata", "ciao", "victim"), geoms, traces,
                     nocs=PAPER_NOCS)
    run = grid.run()
    assert run.report.n_points == 4 * 2 * 3
    assert run.report.n_executables <= 4
    assert run.report.n_executables == 2
    for pt, r in zip(grid.points, run.results):
        assert same_result(
            r, simulate(pt.arch, pt.trace, pt.geom, noc=pt.noc)), \
            (pt.arch, pt.noc, pt.geom.noc_bw)


def test_sweep_grid_rejects_unknown_noc():
    with pytest.raises(ValueError, match="noc must be one of"):
        SweepGrid(("ata",), None, [_trace(rounds=8)], nocs=("bogus",))


def test_sweep_grid_rejects_noc_stack_dataflow_mismatch():
    """A model that claims the shared family but carries extra state
    must be rejected by name, not by an opaque lax.switch error."""
    @dataclasses.dataclass(frozen=True)
    class LeakyNoc(NocModel):
        name: str = "test_leaky"

        def transit(self, geom, state, traffic):
            zeros = jnp.zeros_like(traffic.flits)
            state = dict(state, extra=jnp.float32(0.0))  # illegal key
            from repro.core.noc.base import NocTransit
            return NocTransit(state=state, delay=zeros, occupancy=zeros)

    register_noc(LeakyNoc(), overwrite=True)
    try:
        with pytest.raises(ValueError, match="test_leaky"):
            SweepGrid(("ata",), None, [_trace(rounds=8)],
                      nocs=("ideal", "test_leaky"))
    finally:
        from repro.core.noc import _REGISTRY
        _REGISTRY.pop("test_leaky", None)


def test_new_noc_model_plugs_in_without_core_edits():
    """Registry extension: a degenerate zero-delay model that keeps the
    uniform state is immediately simulatable and stackable."""
    from repro.core.noc.base import NocTransit

    @dataclasses.dataclass(frozen=True)
    class FlatNoc(NocModel):
        name: str = "test_flat"

        def transit(self, geom, state, traffic):
            zeros = jnp.zeros_like(traffic.flits)
            total = jnp.sum(jnp.where(traffic.mask, traffic.flits, 0.0))
            state = self._count(state, traffic, zeros,
                                injected=total, delivered=total)
            return NocTransit(state=state, delay=zeros, occupancy=zeros)

    register_noc(FlatNoc(), overwrite=True)
    try:
        tr = _trace(rounds=48)
        flat = simulate("ata", tr, noc="test_flat")
        assert same_result(flat, simulate("ata", tr))  # zero-delay == ideal
        grid = SweepGrid(("ata",), None, [tr], nocs=("ideal", "test_flat"))
        run = grid.run()
        assert run.report.n_executables == 1      # stacks with the family
        assert same_result(run.results[0], run.results[1])
    finally:
        from repro.core.noc import _REGISTRY
        _REGISTRY.pop("test_flat", None)


# ---------------------------------------------------------------------------
# report: noc section + gate; fig_noc_topology
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def noc_report():
    return sensitivity.run_sensitivity(
        app="cfd", archs=("private", "ata"), knobs={"hide": (5.0, 10.0)},
        kernels_per_app=1, rounds=64,
        mix_pairings=(("cfd", "HS3D"),), noc_models=PAPER_NOCS)


def test_schema_tag_is_contiguous_coverage():
    """A noc-only report cannot claim schema 3 while dropping mix
    coverage: the tag is the highest *contiguous* section level."""
    rep = sensitivity.run_sensitivity(
        app="cfd", archs=("ata",), knobs={"hide": (10.0,)},
        kernels_per_app=1, rounds=48,
        noc_models=("ideal",))             # noc without mix
    assert rep["schema"] == 1 and "noc" in rep and "mix" not in rep


def test_report_noc_section_structure_and_markdown(noc_report, tmp_path):
    rep = noc_report
    assert rep["schema"] == sensitivity.SCHEMA_VERSION == 3
    assert "mix" in rep                   # schema 3 = mix AND noc
    noc = rep["noc"]
    assert len(noc["cells"]) == 2 * 3 * len(sensitivity.NOC_BW_VALUES)
    for cell in noc["cells"]:
        assert cell["noc"] in PAPER_NOCS
        assert cell["ipc"] > 0
        if cell["noc"] == "ideal":
            assert cell["noc_mean_queue_delay"] == 0.0
    # one executable per arch family, not per topology
    assert noc["sweep"]["n_executables"] == 2
    md_path = sensitivity.write_report(str(tmp_path / "rep.json"), rep)
    md = open(md_path).read()
    assert "Interconnect topology sensitivity" in md
    assert "| ata | crossbar |" in md
    again = sensitivity.load_report(str(tmp_path / "rep.json"))
    assert again == json.loads(json.dumps(rep))


def test_gate_covers_noc_section(noc_report):
    rep = noc_report
    assert sensitivity.compare_reports(rep, rep) == []
    # a schema-1/2 baseline tolerates the new section
    old = json.loads(json.dumps(rep))
    del old["noc"]
    old["schema"] = 2 if "mix" in old else 1
    assert sensitivity.compare_reports(old, rep) == []
    # drift inside the noc section is gated when both reports carry it
    drifted = json.loads(json.dumps(rep))
    drifted["noc"]["cells"][0]["ipc"] *= 1.5
    fails = sensitivity.compare_reports(rep, drifted)
    assert len(fails) == 1 and "noc" in fails[0] and "IPC drift" in fails[0]
    missing = json.loads(json.dumps(rep))
    del missing["noc"]
    assert any("noc section missing" in f
               for f in sensitivity.compare_reports(rep, missing))


def test_fig_noc_topology_gap_changes_monotonically(capsys):
    """ISSUE-5 acceptance: crossbar/ring close the ata-vs-private IPC
    gap monotonically as noc_bw shrinks; ideal is flat by
    construction."""
    from benchmarks import fig_noc_topology
    bws = (4.0, 8.0, 16.0, 32.0)
    out = fig_noc_topology.run(kernels_per_app=1, rounds=96,
                               archs=("private", "ata"), noc_bw=bws)
    ideal = [out[("ideal", v, "ata_vs_private")] for v in bws]
    assert max(ideal) - min(ideal) < 1e-6
    for noc in ("crossbar", "ring"):
        gaps = [out[(noc, v, "ata_vs_private")] for v in bws]
        assert all(a <= b + 1e-9 for a, b in zip(gaps, gaps[1:])), \
            (noc, gaps)
        assert gaps[0] < gaps[-1]     # the topology actually bites
        assert gaps[-1] <= ideal[-1] + 1e-6
    printed = capsys.readouterr().out
    assert "fig_noc.cfd.crossbar.noc_bw=4.ata_vs_private" in printed
    assert "fig_noc.executables" in printed
