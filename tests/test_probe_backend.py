"""Probe-backend exact equivalence: lax vs lax_unfused vs
pallas_interpret.

The probe backend (``repro.core.probe``) is a *static* axis of the
simulator — every backend lowers a structurally different program but
must return bit-identical integers/booleans, so every committed golden
is backend-invariant. These tests pin that at three levels: the fused
op itself, a full ``l1_stage`` (outputs *and* post-touch tag state),
and end-to-end ``SimResult`` equality (solo, mix, and non-ideal NoC),
plus the ``SweepGrid`` axis semantics (per-backend executables,
identical results).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (APPS, PAPER_GEOMETRY, SweepGrid, SweepPoint,
                        WorkloadMix, make_trace, simulate)
from repro.core import tagarray
from repro.core.arch import get_arch
from repro.core.geometry import GpuGeometry
from repro.core.probe import (DEFAULT_PROBE_BACKEND, PROBE_BACKENDS,
                              check_probe_backend, fused_probe_rank)
from repro.core.simulator import _l1_state, _request_batch

RNG = np.random.default_rng(7)

#: backends runnable on CPU — "pallas" (Mosaic-compiled) needs a TPU.
CPU_BACKENDS = ("lax", "lax_unfused", "pallas_interpret")

SMALL = dataclasses.replace(PAPER_GEOMETRY, n_cores=6, cluster_size=3,
                            l1_sets=4, l1_ways=8)


def _warmed_state(geom: GpuGeometry, policy=None, fill_frac=0.6, seed=0):
    """A tag state with random valid/dirty lines (set-aligned tags)."""
    rng = np.random.default_rng(seed)
    C, S, W = geom.n_cores, geom.l1_sets, geom.l1_ways
    st = (_l1_state(geom, [policy]) if policy is not None
          else tagarray.init_tag_state(C, S, W))
    tags = rng.integers(0, 64, (C, S, W))
    valid = rng.random((C, S, W)) < fill_frac
    dirty = valid & (rng.random((C, S, W)) < 0.2)
    return dict(st, tags=jnp.asarray(tags * S + np.arange(S)[None, :, None],
                                     jnp.int32),
                valid=jnp.asarray(valid),
                dirty=jnp.asarray(dirty))


def _random_reqs(geom: GpuGeometry, m=4, seed=1):
    rng = np.random.default_rng(seed)
    C = geom.n_cores
    addr = jnp.asarray(rng.integers(0, 64 * geom.l1_sets, (C, m)),
                       jnp.int32)
    is_write = jnp.asarray(rng.random((C, m)) < 0.25)
    return _request_batch(geom, addr, is_write)


def _tree_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# op level
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("geom,m", [(SMALL, 4), (PAPER_GEOMETRY, 2),
                                    (PAPER_GEOMETRY, 5)],
                         ids=["small", "paper", "padded"])
@pytest.mark.parametrize("backend",
                         [b for b in CPU_BACKENDS if b != "lax"])
def test_fused_probe_rank_backends_bitexact(geom, m, backend):
    # m=5 -> R=150, not a multiple of the kernel's BR=128: exercises
    # the dead-lane padding path of the pallas wrapper.
    l1 = _warmed_state(geom)
    reqs = _random_reqs(geom, m=m)
    pre = jnp.asarray(RNG.random(reqs.addr.shape[0]) < 0.1)
    for pre_served in (None, pre):
        ref = fused_probe_rank(geom, l1, reqs, pre_served=pre_served,
                               backend="lax")
        got = fused_probe_rank(geom, l1, reqs, pre_served=pre_served,
                               backend=backend)
        lh = np.asarray(ref.local_hit)
        assert lh.any(), "warmed state should produce some local hits"
        np.testing.assert_array_equal(np.asarray(got.local_hit), lh)
        # touch_way is only consumed (and only defined) where local_hit
        np.testing.assert_array_equal(
            np.where(lh, np.asarray(got.touch_way), 0),
            np.where(lh, np.asarray(ref.touch_way), 0))
        np.testing.assert_array_equal(np.asarray(got.remote_ok),
                                      np.asarray(ref.remote_ok))
        rok = np.asarray(ref.remote_ok)
        assert rok.any(), "warmed state should produce remote hits"
        for field in ("src_cache", "prank", "psize"):
            np.testing.assert_array_equal(
                np.where(rok, np.asarray(getattr(got, field)), 0),
                np.where(rok, np.asarray(getattr(ref, field)), 0))


# ---------------------------------------------------------------------------
# stage level: outputs AND the post-touch tag state
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["ata", "ata_fifo", "ata_bypass",
                                  "victim"])
def test_l1_stage_bitexact_across_backends(arch):
    policy = get_arch(arch)
    geom = SMALL
    l1 = _warmed_state(geom, policy=policy)
    reqs = _random_reqs(geom, seed=3)
    ref = policy.l1_stage(geom, l1, reqs, jnp.int32(5), backend="lax")
    for backend in CPU_BACKENDS[1:]:
        got = policy.l1_stage(geom, l1, reqs, jnp.int32(5),
                              backend=backend)
        _tree_equal(got, ref)


# ---------------------------------------------------------------------------
# end to end: SimResult equality on solo / mix / non-ideal NoC points
# ---------------------------------------------------------------------------
def _small_app(app, **over):
    return dataclasses.replace(APPS[app], rounds=96, **over)


@pytest.mark.parametrize("arch", ["ata", "ata_bypass", "victim"])
def test_simulate_backend_invariant_solo(arch):
    tr = make_trace(_small_app("cfd"))
    ref = simulate(arch, tr, probe_backend="lax")
    assert ref.ipc > 0
    for backend in CPU_BACKENDS[1:]:
        assert simulate(arch, tr, probe_backend=backend) == ref


def test_simulate_backend_invariant_padded_round():
    """R = 30 * 5 = 150 requests per round — not a multiple of the
    kernel tile. Pad lanes must be dead in the arbitration too, not
    just in the probe."""
    tr = make_trace(_small_app("cfd", m=5))
    ref = simulate("ata", tr, probe_backend="lax")
    assert simulate("ata", tr, probe_backend="pallas_interpret") == ref


def test_simulate_backend_invariant_mix_and_noc():
    mix = WorkloadMix(apps=(_small_app("cfd"), _small_app("HS3D")))
    tr = mix.compose()
    ref = simulate("ata", tr, probe_backend="lax")
    assert simulate("ata", tr, probe_backend="pallas_interpret") == ref

    solo = make_trace(_small_app("cfd"))
    ref_noc = simulate("ata", solo, noc="crossbar", probe_backend="lax")
    assert simulate("ata", solo, noc="crossbar",
                    probe_backend="pallas_interpret") == ref_noc


# ---------------------------------------------------------------------------
# sweep axis semantics
# ---------------------------------------------------------------------------
def test_sweep_grid_backend_axis_bitexact_and_buckets_apart():
    tr = make_trace(_small_app("cfd"))
    grid = SweepGrid(["ata"], [PAPER_GEOMETRY], [tr],
                     probe_backends=CPU_BACKENDS)
    run = grid.run()
    assert run.report.n_points == 3
    # backends lower different programs: one executable each
    assert run.report.n_executables == 3
    ref = simulate("ata", tr, probe_backend="lax")
    for point, res in zip(grid.points, run.results):
        assert point.probe_backend in CPU_BACKENDS
        assert res == ref


def test_sweep_point_backend_defaults_to_lax():
    tr = make_trace(_small_app("cfd"))
    assert SweepPoint("ata", PAPER_GEOMETRY, tr,
                      "ideal").probe_backend == "lax"
    assert DEFAULT_PROBE_BACKEND == "lax"
    assert PROBE_BACKENDS == ("lax", "lax_unfused", "pallas",
                              "pallas_interpret")


def test_unknown_backend_rejected():
    tr = make_trace(_small_app("cfd"))
    with pytest.raises(ValueError, match="probe_backend"):
        simulate("ata", tr, probe_backend="fancy")
    with pytest.raises(ValueError, match="probe_backend"):
        check_probe_backend("lax ")
    with pytest.raises(ValueError, match="probe_backend"):
        SweepGrid(["ata"], [PAPER_GEOMETRY], [tr],
                  probe_backends=["lax", "fancy"])


# ---------------------------------------------------------------------------
# the rounds/sec regression gate (benchmarks.sim_speed reports)
# ---------------------------------------------------------------------------
def _simspeed_report(rps_lax=4500.0, rps_unfused=4200.0, execs=7,
                     rounds=64):
    from repro.core.report import compare_simspeed  # noqa: F401
    return {
        "kind": "simspeed", "schema": 1,
        "config": {"app": "cfd", "kernel": 0, "arch": "ata",
                   "rounds": rounds, "n_geoms": 13},
        "sweep": {"n_executables": 2 * execs},
        "cells": [
            {"backend": "lax", "rounds_per_sec": rps_lax, "wall_s": 1.0,
             "n_points": 13, "rounds": rounds, "n_executables": execs},
            {"backend": "lax_unfused", "rounds_per_sec": rps_unfused,
             "wall_s": 1.0, "n_points": 13, "rounds": rounds,
             "n_executables": execs},
        ],
        "headline": {"fused_speedup": rps_lax / rps_unfused},
    }


def test_compare_simspeed_gates_the_ratio_one_sided():
    from repro.core.report import compare_simspeed
    base = _simspeed_report(rps_lax=4500.0, rps_unfused=4200.0)  # 1.07x
    assert compare_simspeed(base, base) == []
    # absolute throughput halves on a slower host: ratio intact -> OK
    slower_host = _simspeed_report(rps_lax=2250.0, rps_unfused=2100.0)
    assert compare_simspeed(base, slower_host) == []
    # a *faster* fused path is never a regression
    better = _simspeed_report(rps_lax=6000.0, rps_unfused=4200.0)
    assert compare_simspeed(base, better) == []
    # fused win collapses below the floor -> fail
    lost = _simspeed_report(rps_lax=2900.0, rps_unfused=4200.0)  # 0.69x
    fails = compare_simspeed(base, lost, speedup_rtol=0.30)
    assert any("fused speedup fell" in f for f in fails)
    # within the tolerance band -> OK
    drifted = _simspeed_report(rps_lax=4000.0, rps_unfused=4200.0)
    assert compare_simspeed(base, drifted, speedup_rtol=0.30) == []


def test_compare_simspeed_structural_failures():
    from repro.core.report import compare_simspeed
    base = _simspeed_report()
    missing = _simspeed_report()
    missing["cells"] = missing["cells"][:1]
    del missing["headline"]["fused_speedup"]
    fails = compare_simspeed(base, missing)
    assert any("backend missing" in f for f in fails)
    assert any("headline missing" in f for f in fails)

    grown = _simspeed_report(execs=9)
    assert any("executable count grew" in f
               for f in compare_simspeed(base, grown))

    other_cfg = _simspeed_report(rounds=96)
    assert any("config mismatch" in f
               for f in compare_simspeed(base, other_cfg))

    not_simspeed = dict(base, kind="sensitivity")
    assert any("not a simspeed report" in f
               for f in compare_simspeed(base, not_simspeed))

    # absolute rounds/sec is gated only when opted in
    slow = _simspeed_report(rps_lax=2250.0, rps_unfused=2100.0)
    assert compare_simspeed(base, slow) == []
    fails = compare_simspeed(base, slow, rps_rtol=0.25)
    assert sum("rounds/sec fell" in f for f in fails) == 2


def test_sim_speed_benchmark_reports_and_self_gates(tmp_path):
    """One tiny end-to-end run of benchmarks.sim_speed: the report it
    writes must carry every gated field and pass its own gate."""
    from benchmarks import sim_speed
    from repro.core.report import compare_simspeed
    path = str(tmp_path / "simspeed.json")
    rep = sim_speed.run(rounds=16, reps=1, geoms=[SMALL],
                        out_json=path)
    assert rep["kind"] == "simspeed"
    assert {c["backend"] for c in rep["cells"]} \
        == {"lax", "lax_unfused"}
    assert all(c["rounds_per_sec"] > 0 for c in rep["cells"])
    assert rep["headline"]["fused_speedup"] > 0
    import json as _json
    with open(path) as f:
        on_disk = _json.load(f)
    assert compare_simspeed(on_disk, rep) == []


def test_non_ata_archs_ignore_backend():
    """The axis is ATA-family-only: other policies accept and ignore
    it, so one grid can mix families without a signature split."""
    tr = make_trace(_small_app("cfd"))
    for arch in ("private", "remote", "decoupled"):
        ref = simulate(arch, tr, probe_backend="lax")
        assert simulate(arch, tr, probe_backend="pallas_interpret") == ref
