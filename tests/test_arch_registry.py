"""Refactor-equivalence + registry + batch-sweep tests (no hypothesis).

The golden numbers below were produced by the pre-refactor monolithic
``simulator._round`` (seed commit) on fixed traces; the registry-based
policy pipeline must reproduce them bit-for-bit for all four paper
architectures.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (APPS, ARCHITECTURES, ReplacementPolicy, Trace,
                        get_arch, make_trace, register_arch,
                        registered_archs, simulate, simulate_batch,
                        simulate_many)
from repro.core import tagarray
from repro.core.arch import ArchPolicy, AtaPolicy, PAPER_ARCHITECTURES

# SimResult fields from the seed (pre-arch-split) simulator, traces:
# dataclasses.replace(APPS[app], rounds=192), kernel=1.
GOLDEN = {
    ("cfd", "private"): dict(
        ipc=48.13981554281181, l1_latency=32.0,
        local_hit_rate=0.1287326388888889, remote_hit_rate=0.0,
        l1_hit_rate=0.1287326388888889, l2_accesses=10037.0,
        dram_accesses=5707.0, noc_flits=40148.0,
        cycles=7029.44677734375, instructions=338396.27122934104),
    ("cfd", "remote"): dict(
        ipc=45.47783321894619, l1_latency=47.09734693877551,
        local_hit_rate=0.1287326388888889, remote_hit_rate=0.20625,
        l1_hit_rate=0.3349826388888889, l2_accesses=7661.0,
        dram_accesses=5707.0, noc_flits=130481.0,
        cycles=7440.90576171875, instructions=338396.27122934104),
    ("cfd", "decoupled"): dict(
        ipc=48.866869537984314, l1_latency=50.52785388127854,
        local_hit_rate=0.3125, remote_hit_rate=0.0,
        l1_hit_rate=0.3125, l2_accesses=7920.0,
        dram_accesses=5712.0, noc_flits=46080.0,
        cycles=6924.86083984375, instructions=338396.27122934104),
    ("cfd", "ata"): dict(
        ipc=49.954089536322286, l1_latency=34.17364016736402,
        local_hit_rate=0.1287326388888889,
        remote_hit_rate=0.16770833333333332,
        l1_hit_rate=0.2964409722222222, l2_accesses=8105.0,
        dram_accesses=5707.0, noc_flits=40148.0,
        cycles=6774.1455078125, instructions=338396.27122934104),
    ("HS3D", "private"): dict(
        ipc=19.030607132323443, l1_latency=32.0,
        local_hit_rate=0.20598958333333334, remote_hit_rate=0.0,
        l1_hit_rate=0.20598958333333334, l2_accesses=18294.0,
        dram_accesses=17416.0, noc_flits=75024.0,
        cycles=8679.841796875, instructions=165182.6592070485),
    ("HS3D", "remote"): dict(
        ipc=16.818281729987405, l1_latency=34.58079545454545,
        local_hit_rate=0.20598958333333334,
        remote_hit_rate=0.01506076388888889,
        l1_hit_rate=0.22105034722222222, l2_accesses=17947.0,
        dram_accesses=17416.0, noc_flits=239670.0,
        cycles=9821.61328125, instructions=165182.6592070485),
    ("HS3D", "decoupled"): dict(
        ipc=18.24013462975359, l1_latency=54.798122065727696,
        local_hit_rate=0.19644097222222223, remote_hit_rate=0.0,
        l1_hit_rate=0.19644097222222223, l2_accesses=18514.0,
        dram_accesses=17437.0, noc_flits=92280.0,
        cycles=9056.0, instructions=165182.6592070485),
    ("HS3D", "ata"): dict(
        ipc=19.12823515147109, l1_latency=32.11472275334608,
        local_hit_rate=0.20598958333333334,
        remote_hit_rate=0.01115451388888889,
        l1_hit_rate=0.21714409722222222, l2_accesses=18037.0,
        dram_accesses=17416.0, noc_flits=75024.0,
        cycles=8635.541015625, instructions=165182.6592070485),
}

INTEGRAL_FIELDS = ("l2_accesses", "dram_accesses", "noc_flits")


def _fixed_trace(app: str) -> Trace:
    return make_trace(dataclasses.replace(APPS[app], rounds=192), kernel=1)


# ---------------------------------------------------------------------------
# refactor equivalence: policies through the registry == seed monolith
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("app,arch", sorted(GOLDEN))
def test_policy_matches_pre_refactor_golden(app, arch):
    r = simulate(arch, _fixed_trace(app))._asdict()
    for field, want in GOLDEN[(app, arch)].items():
        if field in INTEGRAL_FIELDS:
            assert r[field] == want, (field, r[field], want)
        else:
            # identical on the machine that produced the goldens; the
            # tolerance only absorbs cross-platform libm differences
            np.testing.assert_allclose(r[field], want, rtol=1e-6,
                                       err_msg=f"{app}/{arch}/{field}")


# ---------------------------------------------------------------------------
# batch sweep == per-trace simulate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ("private", "ata", "ata_bypass"))
def test_simulate_batch_matches_single(arch):
    p = dataclasses.replace(APPS["cfd"], rounds=128)
    traces = [make_trace(p, kernel=k) for k in range(3)]
    batched = simulate_batch(arch, traces)
    singles = [simulate(arch, t) for t in traces]
    assert len(batched) == len(singles)
    for b, s in zip(batched, singles):
        assert tuple(b) == tuple(s)


def test_simulate_batch_rejects_mixed_shapes():
    t_a = make_trace(dataclasses.replace(APPS["cfd"], rounds=128))
    t_b = make_trace(dataclasses.replace(APPS["HS3D"], rounds=128))
    with pytest.raises(ValueError, match="same-shape"):
        simulate_batch("ata", [t_a, t_b])
    # simulate_many groups by shape and preserves order
    out = simulate_many("ata", [t_a, t_b, t_a])
    assert tuple(out[0]) == tuple(out[2])
    assert tuple(out[0]) == tuple(simulate("ata", t_a))
    assert tuple(out[1]) == tuple(simulate("ata", t_b))


# ---------------------------------------------------------------------------
# registry behaviour
# ---------------------------------------------------------------------------
def test_registry_contains_paper_and_extension_archs():
    archs = registered_archs()
    assert set(PAPER_ARCHITECTURES) <= set(archs)
    assert ARCHITECTURES == PAPER_ARCHITECTURES
    assert "ata_bypass" in archs
    assert "ata_fifo" in archs
    assert get_arch("ata_fifo").replacement is ReplacementPolicy.FIFO


def test_register_arch_rejects_duplicates_and_non_policies():
    with pytest.raises(ValueError, match="already registered"):
        register_arch(AtaPolicy())
    with pytest.raises(TypeError):
        register_arch("ata")  # type: ignore[arg-type]
    with pytest.raises(ValueError, match="unknown architecture"):
        get_arch("no_such_arch")
    with pytest.raises(ValueError, match="arch must be one of"):
        simulate("no_such_arch", _fixed_trace("cfd"))
    # the collision must leave the registered policy untouched
    assert get_arch("ata").replacement is ReplacementPolicy.LRU


#: Built-in registration order (arch/__init__.py import side effects);
#: figures and sweep bucketing rely on it being deterministic.
BUILTIN_ORDER = ("private", "remote", "decoupled", "ata", "ata_bypass",
                 "ata_fifo", "ciao", "victim")


def test_registered_archs_ordering_is_stable():
    archs = registered_archs()
    # insertion order, deterministic across calls; tests may append
    # temporary policies, so compare the builtin subsequence
    builtins = tuple(a for a in archs if a in BUILTIN_ORDER)
    assert builtins == BUILTIN_ORDER
    assert registered_archs() == archs
    # overwrite=True keeps the original slot (dict update semantics)
    register_arch(AtaPolicy(), overwrite=True)
    assert tuple(a for a in registered_archs()
                 if a in BUILTIN_ORDER) == BUILTIN_ORDER


def test_new_policy_plugs_in_without_core_edits():
    @dataclasses.dataclass(frozen=True)
    class PrivateFifo(get_arch("private").__class__):
        name: str = "test_private_fifo"
        replacement: ReplacementPolicy = ReplacementPolicy.FIFO

    register_arch(PrivateFifo(), overwrite=True)
    try:
        r = simulate("test_private_fifo", _fixed_trace("cfd"))
        assert np.isfinite(r.ipc) and r.remote_hit_rate == 0.0
    finally:
        from repro.core.arch import _REGISTRY
        _REGISTRY.pop("test_private_fifo", None)


# ---------------------------------------------------------------------------
# extension variants do something sensible
# ---------------------------------------------------------------------------
def test_ata_bypass_cuts_noc_traffic_on_streaming_app():
    # long enough that L1 sets are full and dead victims exist
    tr = make_trace(dataclasses.replace(APPS["HS3D"], rounds=768))
    base = simulate("ata", tr)
    byp = simulate("ata_bypass", tr)
    # it is a *different* policy, not a re-badged ata ...
    assert tuple(byp) != tuple(base)
    # ... that trades a sliver of hit rate for fill/write-back traffic
    assert byp.noc_flits < 0.95 * base.noc_flits
    assert byp.ipc > 0.95 * base.ipc
    assert byp.l1_hit_rate > base.l1_hit_rate - 0.03


def test_replacement_policies_diverge_and_stay_valid():
    tr = make_trace(dataclasses.replace(APPS["cfd"], rounds=768))
    lru = simulate("ata", tr)
    fifo = simulate("ata_fifo", tr)
    assert tuple(fifo) != tuple(lru)
    assert 0.0 < fifo.l1_hit_rate < 1.0
    # LRU should not lose to FIFO badly on a reuse-heavy workload
    assert lru.l1_hit_rate >= fifo.l1_hit_rate - 0.05


def test_tagarray_fifo_and_random_victims():
    import jax.numpy as jnp
    state = tagarray.init_tag_state(1, 1, 2)
    zero = jnp.asarray([0], jnp.int32)

    def fill_one(state, addr, t):
        a = jnp.asarray([addr], jnp.int32)
        _, way, _ = tagarray.probe(state, zero, zero, a,
                                   policy=ReplacementPolicy.FIFO)
        state, _ = tagarray.fill(state, zero, zero, way, a, jnp.int32(t),
                                 jnp.asarray([True]))
        return state

    state = fill_one(state, 10, 0)   # way 0 (invalid first)
    state = fill_one(state, 11, 1)   # way 1
    # touch the *older* line much later: LRU would now evict 11, FIFO
    # still evicts the oldest install, 10.
    state = tagarray.touch(state, zero, zero, jnp.asarray([0]),
                           jnp.int32(5), jnp.asarray([True]))
    _, way_fifo, _ = tagarray.probe(state, zero, zero,
                                    jnp.asarray([99], jnp.int32),
                                    policy=ReplacementPolicy.FIFO)
    _, way_lru, _ = tagarray.probe(state, zero, zero,
                                   jnp.asarray([99], jnp.int32),
                                   policy=ReplacementPolicy.LRU)
    assert int(way_fifo[0]) == 0     # oldest install
    assert int(way_lru[0]) == 1      # least recently touched

    # RANDOM: deterministic per address, prefers invalid ways first
    state2 = tagarray.init_tag_state(1, 1, 4)
    a = jnp.asarray([123], jnp.int32)
    _, w1, _ = tagarray.probe(state2, zero, zero, a,
                              policy=ReplacementPolicy.RANDOM)
    _, w2, _ = tagarray.probe(state2, zero, zero, a,
                              policy=ReplacementPolicy.RANDOM)
    assert int(w1[0]) == int(w2[0]) == 0  # first invalid way
    for addr in (0, 1, 2, 3):             # all-valid: hashed way in range
        full = {k: (v if k != "valid" else jnp.ones_like(v))
                for k, v in state2.items()}
        _, w, _ = tagarray.probe(full, zero, zero,
                                 jnp.asarray([addr], jnp.int32),
                                 policy=ReplacementPolicy.RANDOM)
        assert 0 <= int(w[0]) < 4


# ---------------------------------------------------------------------------
# workload int32 guard
# ---------------------------------------------------------------------------
def test_trace_addresses_refuse_int32_overflow():
    from repro.core.trace.generators import _require_int32
    ok = np.asarray([[0, 2**26]], np.int64)
    assert _require_int32(ok).dtype == np.int32
    with pytest.raises(ValueError, match="outside int32"):
        _require_int32(np.asarray([2**31], np.int64))
    with pytest.raises(ValueError, match="outside int32"):
        _require_int32(np.asarray([-1], np.int64))
