"""Trace-layer tests: the ``repro.core.trace`` package split, strict
``Trace`` boundary validation, the kernel-0 calibration convention, and
the per-app attribution conservation invariants.

(The hypothesis variant — per-app attribution is invariant under app
relabeling — lives in test_properties.py.)
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (APPS, PAPER_GEOMETRY, Trace, WorkloadMix,
                        kernel_params, make_trace, simulate, trace_kind)
from repro.core.trace import generators


def _small(app, rounds=192):
    return dataclasses.replace(APPS[app], rounds=rounds)


# ---------------------------------------------------------------------------
# the workloads.py shim is gone (deprecated in PR 4, removed in PR 7)
# ---------------------------------------------------------------------------
def test_workloads_shim_removed():
    with pytest.raises(ImportError):
        from repro.core import workloads  # noqa: F401


# ---------------------------------------------------------------------------
# kernel-0 convention: the canonical calibration kernel is jitter-free
# ---------------------------------------------------------------------------
def test_kernel_zero_is_canonical_calibration_kernel():
    """Regression pin: kernel 0 uses the app's raw calibrated params;
    kernels >= 1 are deterministically jittered. Pre-split this was a
    truthiness accident (``if kernel``); it is now deliberate API."""
    app = APPS["cfd"]
    assert kernel_params(app, 0) is app
    j1 = kernel_params(app, 1)
    assert j1 != app                        # genuinely jittered
    assert kernel_params(app, 1) == j1      # and deterministic
    assert kernel_params(app, 2) != j1      # per-kernel draws differ
    with pytest.raises(ValueError, match="kernel must be >= 0"):
        kernel_params(app, -1)


def test_make_trace_kernel_zero_uses_raw_params():
    app = _small("doitgen")
    t0 = make_trace(app, kernel=0)
    t1 = make_trace(app, kernel=1)
    assert t0.insn_per_req == app.insn_per_req
    assert t1.insn_per_req == kernel_params(app, 1).insn_per_req
    assert not np.array_equal(t0.addr, t1.addr)


# ---------------------------------------------------------------------------
# strict Trace construction
# ---------------------------------------------------------------------------
def _raw(dtype_addr=np.int32, dtype_write=np.bool_, shape=(4, 6, 2)):
    rng = np.random.default_rng(0)
    addr = rng.integers(0, 64, shape).astype(dtype_addr)
    is_write = rng.random(shape) < 0.2
    return addr, is_write.astype(dtype_write)


def test_trace_rejects_non_int32_addr():
    addr, w = _raw(np.int64)
    with pytest.raises(ValueError, match="must be int32"):
        Trace(addr=addr, is_write=w, insn_per_req=4.0)


def test_trace_rejects_non_bool_is_write():
    addr, w = _raw()
    with pytest.raises(ValueError, match="must be bool"):
        Trace(addr=addr, is_write=w.astype(np.int8), insn_per_req=4.0)


def test_trace_rejects_shape_mismatch_and_bad_ndim():
    addr, w = _raw()
    with pytest.raises(ValueError, match="shape"):
        Trace(addr=addr, is_write=w[:, :-1], insn_per_req=4.0)
    with pytest.raises(ValueError, match="rounds, cores, m"):
        Trace(addr=addr[0], is_write=w[0], insn_per_req=4.0)


def test_trace_insn_vector_validation_and_collapse():
    addr, w = _raw()                        # C = 6
    with pytest.raises(ValueError, match="per-core vector"):
        Trace(addr=addr, is_write=w, insn_per_req=np.ones(5))
    # uniform vector collapses to the canonical scalar form
    t = Trace(addr=addr, is_write=w, insn_per_req=np.full(6, 3.0))
    assert isinstance(t.insn_per_req, float) and t.insn_per_req == 3.0
    t2 = Trace(addr=addr, is_write=w,
               insn_per_req=np.asarray([3.0, 3.0, 3.0, 5.0, 5.0, 5.0]))
    assert np.shape(t2.insn_per_req) == (6,)
    assert t2.insn_vector.tolist() == [3, 3, 3, 5, 5, 5]


def test_trace_core_app_validation_and_collapse():
    addr, w = _raw()
    with pytest.raises(ValueError, match="integer app ids"):
        Trace(addr=addr, is_write=w, insn_per_req=4.0,
              core_app=np.zeros(6, np.float32))
    with pytest.raises(ValueError, match="one app id per"):
        Trace(addr=addr, is_write=w, insn_per_req=4.0,
              core_app=np.zeros(5, np.int32))
    with pytest.raises(ValueError, match="dense"):
        Trace(addr=addr, is_write=w, insn_per_req=4.0,
              core_app=np.asarray([0, 0, 0, 2, 2, 2]))
    # single-app assignment collapses to the canonical solo form
    t = Trace(addr=addr, is_write=w, insn_per_req=4.0,
              core_app=np.zeros(6, np.int64))
    assert t.core_app is None and t.n_apps == 1
    t2 = Trace(addr=addr, is_write=w, insn_per_req=4.0,
               core_app=np.asarray([0, 0, 1, 1, 1, 1]))
    assert t2.n_apps == 2 and t2.core_app.dtype == np.int32
    assert trace_kind(t2) == ((4, 6, 2), (), 2)


# ---------------------------------------------------------------------------
# per-app attribution: conservation invariants
# ---------------------------------------------------------------------------
def test_solo_trace_per_app_block_covers_everything():
    tr = make_trace(_small("cfd"))
    r = simulate("ata", tr)
    assert len(r.per_app) == 1
    (a,) = r.per_app
    T, C, m = tr.addr.shape
    assert a.cores == C
    assert a.requests == T * C * m
    assert a.instructions == pytest.approx(r.instructions, rel=1e-12)
    assert a.cycles == r.cycles
    assert a.local_hit_rate == pytest.approx(r.local_hit_rate)
    assert a.remote_hit_rate == pytest.approx(r.remote_hit_rate)
    assert a.l1_latency == pytest.approx(r.l1_latency)


@pytest.mark.parametrize("arch", ["private", "ata"])
def test_mix_per_app_attribution_conserves_totals(arch):
    mix = WorkloadMix(apps=("cfd", "HS3D"), rounds=192)
    tr = mix.compose(PAPER_GEOMETRY.n_cores)
    r = simulate(arch, tr)
    T, C, m = tr.addr.shape
    assert len(r.per_app) == 2
    assert sum(a.cores for a in r.per_app) == C
    assert sum(a.requests for a in r.per_app) == T * C * m
    # hit counts are small integers in float32: sums are exact up to
    # the rate's own rounding
    assert sum(a.local_hits for a in r.per_app) \
        == pytest.approx(r.local_hit_rate * (T * C * m), abs=1e-6)
    assert sum(a.remote_hits for a in r.per_app) \
        == pytest.approx(r.remote_hit_rate * (T * C * m), abs=1e-6)
    # float accumulations: per-app sums re-combine to the totals
    assert sum(a.instructions for a in r.per_app) \
        == pytest.approx(r.instructions, rel=1e-6)
    assert max(a.cycles for a in r.per_app) == r.cycles
    lat_n = sum(a.l1_lat_n for a in r.per_app)
    lat_sum = sum(a.l1_lat_sum for a in r.per_app)
    if lat_n:
        assert lat_sum / lat_n == pytest.approx(r.l1_latency, rel=1e-5)


def test_one_app_mix_bit_exact_with_plain_simulate():
    """A mix of one app on all cores composes to the canonical solo
    trace — same executable, bit-identical results."""
    mix = WorkloadMix(apps=("cfd",), rounds=192)
    composed = mix.compose(PAPER_GEOMETRY.n_cores)
    plain = make_trace(_small("cfd"))
    assert composed.core_app is None
    assert isinstance(composed.insn_per_req, float)
    assert np.array_equal(composed.addr, plain.addr)
    assert np.array_equal(composed.is_write, plain.is_write)
    for arch in ("private", "ata"):
        assert tuple(simulate(arch, composed)) \
            == tuple(simulate(arch, plain)), arch


def test_require_int32_guard_still_reexported():
    ok = np.asarray([[0, 2 ** 26]], np.int64)
    assert generators._require_int32(ok).dtype == np.int32
    with pytest.raises(ValueError, match="outside int32"):
        generators._require_int32(np.asarray([2 ** 31], np.int64))
