"""scripts/bench_trend.py: cross-run drift tracking over a directory of
nightly sensitivity reports (the `bench-history` CI artifact)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SCRIPT = os.path.join(ROOT, "scripts", "bench_trend.py")

sys.path.insert(0, os.path.join(ROOT, "scripts"))
import bench_trend  # noqa: E402


def _report(ipc, ws=2.0, noc_ipc=10.0):
    return {
        "schema": 3,
        "config": {}, "sweep": {"n_executables": 2},
        "cells": [{"arch": "ata", "knob": "noc_bw", "value": 16.0,
                   "ipc": ipc, "l1_hit_rate": 0.5}],
        "mix": {"cells": [{"mix": "cfd+HS3D", "arch": "ata",
                           "weighted_speedup": ws}]},
        "noc": {"cells": [{"arch": "ata", "noc": "crossbar",
                           "noc_bw": 8.0, "ipc": noc_ipc}]},
    }


@pytest.fixture()
def history(tmp_path):
    d = tmp_path / "bench_history"
    d.mkdir()
    for name, rep in [
            ("2026-07-27.json", _report(20.0)),
            ("2026-07-28.json", _report(20.2)),
            ("2026-07-29.json", _report(21.0, ws=2.5, noc_ipc=10.1)),
    ]:
        (d / name).write_text(json.dumps(rep))
    (d / "junk.json").write_text("{not json")          # tolerated
    (d / "notes.txt").write_text("ignored")
    return str(d)


def test_series_cover_solo_mix_and_noc_sections(history):
    reports = bench_trend.load_history(history)
    assert [name for name, _ in reports] \
        == ["2026-07-27", "2026-07-28", "2026-07-29"]
    series = bench_trend._cell_series(reports)
    assert ("solo", "ata", "noc_bw", 16.0, "ipc") in series
    assert ("mix", "cfd+HS3D", "ata", "weighted_speedup") in series
    assert ("noc", "ata", "crossbar", 8.0, "ipc") in series
    assert [v for _, v in
            series[("solo", "ata", "noc_bw", 16.0, "ipc")]] \
        == [20.0, 20.2, 21.0]


def test_trend_rows_flag_drift_beyond_rtol(history):
    reports = bench_trend.load_history(history)
    rows = bench_trend.trend_rows(bench_trend._cell_series(reports),
                                  rtol=0.05)
    by_key = {r["key"]: r for r in rows}
    # solo IPC: latest 21.0 vs median(20.0, 20.2) = 20.1 -> +4.5%, ok
    solo = by_key[("solo", "ata", "noc_bw", 16.0, "ipc")]
    assert not solo["flagged"]
    assert solo["drift"] == pytest.approx((21.0 - 20.1) / 20.1)
    # mix WS: 2.5 vs median 2.0 -> +25%, flagged
    assert by_key[("mix", "cfd+HS3D", "ata", "weighted_speedup")
                  ]["flagged"]
    md = bench_trend.to_markdown(rows, 0.05, len(reports))
    assert "1 cell(s) drifted beyond tolerance" in md
    assert "cfd+HS3D/ata" in md
    csv = bench_trend.to_csv(bench_trend._cell_series(reports))
    assert "solo,ata/noc_bw/16.0,ipc,2026-07-29,21.0" in csv


def _simspeed(rps_lax, rps_unfused, rounds=64):
    return {
        "kind": "simspeed", "schema": 1,
        "config": {"app": "cfd", "kernel": 0, "arch": "ata",
                   "rounds": rounds, "n_geoms": 13},
        "sweep": {"n_executables": 14},
        "cells": [
            {"backend": "lax", "rounds_per_sec": rps_lax,
             "wall_s": 1.0, "n_points": 13, "rounds": rounds,
             "n_executables": 7},
            {"backend": "lax_unfused", "rounds_per_sec": rps_unfused,
             "wall_s": 1.0, "n_points": 13, "rounds": rounds,
             "n_executables": 7},
        ],
        "headline": {"fused_speedup": rps_lax / rps_unfused},
    }


def test_simspeed_reports_join_the_series(tmp_path):
    """Throughput reports live in the same history directory as the
    sensitivity reports; the solo/mix/noc parser must skip them (their
    cells have no arch/knob keys) and emit simspeed series instead."""
    d = tmp_path / "bench_history"
    d.mkdir()
    (d / "2026-08-01.json").write_text(json.dumps(_report(20.0)))
    (d / "2026-08-02_simspeed.json").write_text(
        json.dumps(_simspeed(4400.0, 4000.0)))
    (d / "2026-08-03_simspeed.json").write_text(
        json.dumps(_simspeed(4600.0, 4100.0)))
    series = bench_trend._cell_series(bench_trend.load_history(str(d)))
    assert [v for _, v in series[("simspeed", "lax", "rounds_per_sec")]] \
        == [4400.0, 4600.0]
    assert ("simspeed", "lax_unfused", "rounds_per_sec") in series
    ratios = series[("simspeed", "lax/lax_unfused", "fused_speedup")]
    assert [v for _, v in ratios] == [4400.0 / 4000.0, 4600.0 / 4100.0]
    # the sensitivity report still parses alongside
    assert ("solo", "ata", "noc_bw", 16.0, "ipc") in series
    rows = bench_trend.trend_rows(series, rtol=0.05)
    assert all(not r["flagged"] for r in rows)


def _serving(hit, p99, rps, slots=None, headline=None):
    cell = {"shards": 8, "mix": "chat+rag", "policy": "ata",
            "requests": 4000, "hit_rate": hit,
            "probe_messages": 0, "p99_latency": p99,
            "throughput_rps": rps}
    if slots is not None:
        cell["slots"] = slots
    return {
        "kind": "serving", "schema": 1 if slots is None else 2,
        "config": {"shards": [8], "rounds": 512},
        "cells": [cell],
        "headline": dict({"probes_filtered": 1000}, **(headline or {})),
    }


def test_serving_reports_join_the_series(tmp_path):
    """Serving-engine reports ride the same history: per
    (shards x mix x policy x slots) cell, hit rate + p99 + throughput
    series — pre-batching reports (no ``slots`` key) join the B=1
    series — plus the batched req/s-ratio headline series."""
    d = tmp_path / "bench_history"
    d.mkdir()
    (d / "2026-08-08_serving.json").write_text(
        json.dumps(_serving(0.41, 720.0, 50e3)))
    (d / "2026-08-09_serving.json").write_text(
        json.dumps(_serving(0.41, 726.0, 61e3, slots=1,
                            headline={"batched_slots": 4,
                                      "batched_model_speedup": 3.4,
                                      "batched_wall_speedup": 0.9})))
    (d / "2026-08-09.json").write_text(json.dumps(_report(20.0)))
    series = bench_trend._cell_series(bench_trend.load_history(str(d)))
    key = ("serving", 8, "chat+rag", "ata", 1, "hit_rate")
    assert [v for _, v in series[key]] == [0.41, 0.41]
    assert ("serving", 8, "chat+rag", "ata", 1, "p99_latency") in series
    rps = series[("serving", 8, "chat+rag", "ata", 1, "throughput_rps")]
    assert [v for _, v in rps] == [50e3, 61e3]
    # batched headlines get their own series (only where reported)
    model = series[("serving", "B4/B1", "batched_model_speedup")]
    assert [v for _, v in model] == [3.4]
    assert ("serving", "B4/B1", "batched_wall_speedup") in series
    # sensitivity reports still parse alongside
    assert ("solo", "ata", "noc_bw", 16.0, "ipc") in series
    rows = bench_trend.trend_rows(series, rtol=0.05)
    by_key = {r["key"]: r for r in rows}
    assert not by_key[("serving", 8, "chat+rag", "ata", 1, "hit_rate")
                      ]["flagged"]
    # host throughput may drift beyond rtol — informational by design
    assert by_key[("serving", 8, "chat+rag", "ata", 1, "throughput_rps")
                  ]["flagged"]


def test_cli_writes_outputs_and_strict_gates(history, tmp_path):
    md = str(tmp_path / "trend.md")
    csv = str(tmp_path / "trend.csv")
    r = subprocess.run(
        [sys.executable, SCRIPT, history, "--markdown", md,
         "--csv", csv, "--rtol", "0.05"],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr          # informational default
    assert "1 flagged" in r.stderr
    assert os.path.exists(md) and os.path.exists(csv)
    # --strict turns flagged drift into a failing exit code
    r = subprocess.run(
        [sys.executable, SCRIPT, history, "--rtol", "0.05", "--strict"],
        capture_output=True, text=True)
    assert r.returncode == 1
    # single-report history: tables render, nothing flagged, exit 0
    solo_dir = tmp_path / "one"
    solo_dir.mkdir()
    (solo_dir / "a.json").write_text(json.dumps(_report(20.0)))
    r = subprocess.run(
        [sys.executable, SCRIPT, str(solo_dir), "--strict"],
        capture_output=True, text=True)
    assert r.returncode == 0 and "0 flagged" in r.stderr
    # empty history: "no history yet" markdown + header-only CSV,
    # exit 0 — the first nightly on a fresh cache is not a failure
    empty = tmp_path / "empty"
    empty.mkdir()
    md0 = str(tmp_path / "empty.md")
    r = subprocess.run([sys.executable, SCRIPT, str(empty),
                        "--markdown", md0],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    assert "No history yet" in open(md0).read()
    assert r.stdout == "section,cell,metric,run,value\n"
    # a missing directory behaves like an empty one
    r = subprocess.run(
        [sys.executable, SCRIPT, str(tmp_path / "never_made")],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    assert "no history directory" in r.stderr


def _telemetry(hit=0.36, p50=288.0, p99=720.0):
    return {
        "kind": "telemetry", "schema": 1,
        "config": {"window": 32, "rounds": None},
        "sim": {"arch": "ata", "noc": "crossbar", "app": "cfd",
                "l1_hit_rate": 0.28, "l1_latency": 33.0,
                "p99_latency_bucket": 64.0},
        "serving": {"policy": "ata", "mix": "chat+rag", "shards": 8,
                    "hit_rate": hit, "hist_exact": True,
                    "p50_latency": p50, "p99_latency": p99},
    }


def test_telemetry_reports_join_the_series(tmp_path):
    """Observability captures have no ``cells`` list but still trend:
    histogram-derived latency quantiles and hit rates become
    ``telemetry`` series rows alongside the other report kinds."""
    d = tmp_path / "bench_history"
    d.mkdir()
    (d / "2026-08-08.json").write_text(json.dumps(_report(20.0)))
    (d / "2026-08-08_telemetry.json").write_text(
        json.dumps(_telemetry()))
    (d / "2026-08-09_telemetry.json").write_text(
        json.dumps(_telemetry(p99=726.0)))
    series = bench_trend._cell_series(bench_trend.load_history(str(d)))
    assert [v for _, v in
            series[("telemetry", "ata", "chat+rag", 8, "p99_latency")]] \
        == [720.0, 726.0]
    assert ("telemetry", "ata", "crossbar", "p99_latency_bucket") \
        in series
    assert ("telemetry", "ata", "chat+rag", 8, "p50_latency") in series
    assert ("solo", "ata", "noc_bw", 16.0, "ipc") in series
    rows = bench_trend.trend_rows(series, rtol=0.05)
    assert all(not r["flagged"] for r in rows)
