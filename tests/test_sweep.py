"""SweepGrid engine tests: golden equivalence vs per-point ``simulate``,
executable accounting (policy stacking + scalar-geometry batching),
device sharding (subprocess, 8 forced host devices), and the NaN metric
guards in ``repro.core.metrics``."""
import dataclasses
import math
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import (APPS, PAPER_GEOMETRY, SimResult, SweepGrid,
                        geomean, make_trace, run_suite, simulate)
from repro.core.arch import PAPER_ARCHITECTURES
from repro.core.metrics import AppResult

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _traces(app, rounds=96, kernels=2):
    p = dataclasses.replace(APPS[app], rounds=rounds)
    return [make_trace(p, kernel=k) for k in range(kernels)]


def same_result(a: SimResult, b: SimResult) -> bool:
    """Bit-exact equality that treats identical NaNs as equal.

    ``SimResult.l1_latency`` is documented to be NaN when no load was
    ever fully served inside the L1 complex; grid and per-point paths
    must agree on that too.
    """
    return all(x == y or (x != x and y != y)
               for x, y in zip(tuple(a), tuple(b)))


# ---------------------------------------------------------------------------
# golden equivalence: grid == sequential simulate, bit for bit
# ---------------------------------------------------------------------------
def test_sweep_grid_bit_identical_to_simulate_all_paper_archs():
    traces = _traces("cfd")
    geoms = [PAPER_GEOMETRY, dataclasses.replace(PAPER_GEOMETRY, svc_l2=8)]
    grid = SweepGrid(PAPER_ARCHITECTURES, geoms, traces)
    run = grid.run()
    assert len(run.results) == len(grid.points)
    for pt, r in zip(grid.points, run.results):
        assert same_result(r, simulate(pt.arch, pt.trace, pt.geom)), \
            (pt.arch, pt.geom.svc_l2)


def test_sweep_grid_bit_identical_for_stacked_ata_family():
    """ata/ata_fifo/ata_bypass share one switch-selected executable; each
    variant must still match its own per-point simulate() exactly."""
    # long enough that L1 sets fill and the replacement policies diverge
    # — otherwise a policy_idx that silently selected branch 0 for every
    # point would still pass the equality checks below.
    traces = _traces("cfd", rounds=768, kernels=1)
    grid = SweepGrid(("ata", "ata_fifo", "ata_bypass"), None, traces)
    run = grid.run()
    assert run.report.n_executables == 1
    for pt, r in zip(grid.points, run.results):
        assert same_result(r, simulate(pt.arch, pt.trace))
    by_arch = {pt.arch: r for pt, r in zip(grid.points, run.results)}
    assert tuple(by_arch["ata"]) != tuple(by_arch["ata_fifo"])


# ---------------------------------------------------------------------------
# executable accounting
# ---------------------------------------------------------------------------
def test_scalar_geometries_share_one_executable_per_group():
    """2 dataflow groups x 3 scalar-only geometries x kernels -> exactly
    2 executables (the acceptance-criteria grid, unsharded here)."""
    traces = _traces("doitgen", kernels=3)
    geoms = [PAPER_GEOMETRY,
             dataclasses.replace(PAPER_GEOMETRY, svc_port=4),
             dataclasses.replace(PAPER_GEOMETRY, lat_l2=240)]
    grid = SweepGrid(("private", "ata"), geoms, traces)
    run = grid.run()
    assert run.report.n_points == 2 * 3 * 3
    assert run.report.n_executables == 2, run.report
    # warm second run: same executables, zero fresh compiles
    rerun = SweepGrid(("private", "ata"), geoms, traces).run()
    assert rerun.report.n_compiles == 0
    for a, b in zip(run.results, rerun.results):
        assert tuple(a) == tuple(b)


def test_structural_geometries_group_per_shape():
    traces = _traces("cfd", kernels=1)
    geoms = [PAPER_GEOMETRY,
             dataclasses.replace(PAPER_GEOMETRY, l1_sets=16)]
    run = SweepGrid(("ata",), geoms, traces).run()
    assert run.report.n_executables == 2   # one per structure
    for pt, r in zip(SweepGrid(("ata",), geoms, traces).points,
                     run.results):
        assert same_result(r, simulate(pt.arch, pt.trace, pt.geom))


def test_sweep_grid_validates_archs_and_geometry():
    tr = _traces("cfd", kernels=1)
    with pytest.raises(ValueError, match="arch must be one of"):
        SweepGrid(("no_such_arch",), None, tr)
    with pytest.raises(ValueError, match="must divide"):
        SweepGrid(("ata",),
                  [dataclasses.replace(PAPER_GEOMETRY, cluster_size=7)], tr)


# ---------------------------------------------------------------------------
# suite driver rides the grid
# ---------------------------------------------------------------------------
def test_run_suite_matches_per_point_simulate():
    suite = run_suite(apps=("cfd", "HS3D"), archs=("private", "ata"),
                      kernels_per_app=2, rounds=96)
    for app in ("cfd", "HS3D"):
        traces = [make_trace(dataclasses.replace(APPS[app], rounds=96),
                             kernel=k) for k in range(2)]
        for arch in ("private", "ata"):
            got = suite[app][arch].per_kernel
            assert len(got) == 2
            for tr, r in zip(traces, got):
                assert same_result(r, simulate(arch, tr))


# ---------------------------------------------------------------------------
# device sharding (subprocess: forced 8-device host platform)
# ---------------------------------------------------------------------------
def test_sharded_sweep_on_8_devices_bit_identical():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import dataclasses, jax
        from repro.core import (APPS, PAPER_GEOMETRY, SweepGrid, make_trace,
                                simulate)
        assert len(jax.devices()) == 8
        p = dataclasses.replace(APPS["cfd"], rounds=64)
        traces = [make_trace(p, kernel=k) for k in range(3)]
        geoms = [PAPER_GEOMETRY,
                 dataclasses.replace(PAPER_GEOMETRY, svc_port=4),
                 dataclasses.replace(PAPER_GEOMETRY, lat_dram=400)]
        grid = SweepGrid(("private", "ata"), geoms, traces)
        run = grid.run()
        assert run.report.n_devices == 8, run.report
        assert run.report.n_executables == 2, run.report
        same = lambda a, b: all(x == y or (x != x and y != y)
                                for x, y in zip(tuple(a), tuple(b)))
        for pt, r in zip(grid.points, run.results):
            assert same(r, simulate(pt.arch, pt.trace, pt.geom))
        print("SHARDED_SWEEP_OK", run.report.n_points)
    """)], capture_output=True, text=True, env=env, timeout=900)
    assert "SHARDED_SWEEP_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# NaN metric guards
# ---------------------------------------------------------------------------
def _res(l1_latency, l1_hit_rate=0.5):
    return SimResult(ipc=1.0, l1_latency=l1_latency,
                     local_hit_rate=0.4, remote_hit_rate=0.1,
                     l1_hit_rate=l1_hit_rate, l2_accesses=10.0,
                     dram_accesses=5.0, noc_flits=20.0, cycles=100.0,
                     instructions=100.0)


def test_app_result_latency_ignores_all_streaming_kernel_nan():
    app = AppResult("x", "ata", [_res(30.0), _res(float("nan")),
                                 _res(50.0)])
    assert app.l1_latency == pytest.approx(40.0)
    assert app.l1_hit_rate == pytest.approx(0.5)
    all_nan = AppResult("x", "ata", [_res(float("nan"))])
    assert math.isnan(all_nan.l1_latency)


def test_geomean_rejects_nan_and_nonpositive():
    assert geomean([1.0, 4.0]) == pytest.approx(2.0)
    with pytest.raises(ValueError, match="finite positive"):
        geomean([1.0, float("nan")])
    with pytest.raises(ValueError, match="finite positive"):
        geomean([1.0, 0.0])
    with pytest.raises(ValueError, match="finite positive"):
        geomean([1.0, -2.0])
    with pytest.raises(ValueError, match="empty"):
        geomean([])
