"""Vectorized serving engine vs the retained numpy oracle.

The contract is *bit-exactness*: ``repro.serving.engine.serve_stream``
must reproduce the oracle's hit/probe/fetch accounting integer-for-
integer on the same :class:`~repro.core.trace.serving.RequestStream`,
for every serving policy, both on packed multi-request rounds and on
the sequentialized stream (one request per round — where round
semantics degenerate to the oracle's original one-at-a-time order) —
and at every batched admission width ``B`` (slots replay as sequential
sub-rounds, so counters never move with ``B``).
On top of that: conservation invariants, probe-message bounds, probe-
backend equivalence, NoC pricing conservation, per-tenant attribution,
overflow-headroom accumulation, compile-count bounds (one executable
per policy x backend x B), the committed serving baseline, and the
``compare_serving`` regression gate with its batched-speedup floor.
"""
import numpy as np
import pytest

from repro.core.trace.serving import ServingMix, tenant_stream
from repro.serving import (SERVING_POLICIES, ServingConfig, engine, ref,
                           serve_stream)

N_SHARDS = 4
ROUNDS = 64


@pytest.fixture(scope="module")
def stream():
    # chat+batch: high- and low-sharing tenants with bursty arrivals,
    # past the cold-start transient at 4 shards x 64 rounds
    return ServingMix(("chat", "batch")).make_stream(
        n_shards=N_SHARDS, rounds=ROUNDS, seed=1)


@pytest.fixture(scope="module")
def results(stream):
    return {p: serve_stream(p, stream) for p in SERVING_POLICIES}


@pytest.fixture(scope="module")
def oracle(stream):
    return {p: ref.run_stream(p, ref.AtaCacheConfig(), stream)
            for p in SERVING_POLICIES}


def _assert_matches(res, st):
    assert res.local_hits == st.local_hits
    assert res.remote_hits == st.remote_hits
    assert res.recomputed_blocks == st.recomputed_blocks
    assert res.probe_messages == st.probe_messages
    assert res.remote_fetch_blocks == st.remote_fetch_blocks
    assert res.directory_sync_entries == st.directory_sync_entries
    np.testing.assert_array_equal(res.shard_load, st.shard_load)


# ---------------------------------------------------------------------------
# oracle equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_engine_matches_oracle_packed(results, oracle, policy):
    """Full rounds (up to one request per shard) — bit-exact."""
    _assert_matches(results[policy], oracle[policy])


@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_engine_matches_oracle_sequential(stream, policy):
    """One request per round: the oracle's original sequential order."""
    seq = stream.sequential()
    res = serve_stream(policy, seq)
    st = ref.run_stream(policy, ref.AtaCacheConfig(), seq)
    _assert_matches(res, st)
    # and sequentialization preserves the request population exactly
    assert seq.n_requests == stream.n_requests


def test_oracle_broadcast_is_legacy_remote(stream):
    """`broadcast` is the legacy oracle's `remote` policy by alias."""
    a = ref.run_stream("broadcast", ref.AtaCacheConfig(), stream)
    b = ref.run_stream("remote", ref.AtaCacheConfig(), stream)
    assert (a.local_hits, a.remote_hits, a.probe_messages) \
        == (b.local_hits, b.remote_hits, b.probe_messages)


def test_oracle_rejects_engineless_policies(stream):
    with pytest.raises(ValueError):
        ref.run_stream("decoupled", ref.AtaCacheConfig(), stream)
    with pytest.raises(ValueError):
        serve_stream("decoupled", stream)


# ---------------------------------------------------------------------------
# conservation + bounds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_block_conservation(stream, results, policy):
    """Every valid block is served exactly once: hit or recomputed."""
    res = results[policy]
    total_blocks = int(stream.n_blocks[stream.valid].sum())
    assert (res.local_hits + res.remote_hits + res.recomputed_blocks
            == total_blocks)
    assert res.n_requests == stream.n_requests
    assert int(res.served.sum()) == stream.n_requests


def test_probe_message_bounds(stream, results):
    """private/ata never probe; broadcast probes <= blocks x (C-1)."""
    assert results["private"].probe_messages == 0
    assert results["ata"].probe_messages == 0
    total_blocks = int(stream.n_blocks[stream.valid].sum())
    bcast = results["broadcast"].probe_messages
    assert 0 < bcast <= total_blocks * (N_SHARDS - 1)


def test_ata_replicates_and_syncs(results):
    """ata fetches remotely and fills locally (Fig 7a); every newly
    sealed block is a directory delta all-gather entry; broadcast
    probes instead of syncing."""
    ata = results["ata"]
    assert ata.remote_fetch_blocks > 0
    assert ata.directory_sync_entries == ata.recomputed_blocks
    assert results["broadcast"].directory_sync_entries == 0
    assert results["private"].remote_fetch_blocks == 0


def test_hit_rate_ordering(results):
    """Sharing beats private; zero-cost visibility beats probing."""
    assert results["ata"].hit_rate >= results["broadcast"].hit_rate - 1e-9
    assert results["broadcast"].hit_rate > results["private"].hit_rate


# ---------------------------------------------------------------------------
# batched admission (slots = B)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ("lax", "pallas_interpret"))
@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_engine_matches_oracle_batched(stream, oracle, policy, backend):
    """Every policy x backend x B in {1,2,4} is oracle-exact.

    The oracle sequentializes slots by construction (row order is slot
    order), so one oracle run is the reference for every ``B``.
    """
    cfg = ServingConfig(probe_backend=backend)
    for b in (1, 2, 4):
        _assert_matches(serve_stream(policy, stream.batched(b), cfg),
                        oracle[policy])


def test_batched_equals_slot_sequential_outputs(stream, results):
    """B=4 reproduces the B=1 replay output-for-output — latency grid,
    tenant attribution, shard load — while the throughput model
    charges one round per B admissions (the batching win)."""
    r1 = results["ata"]
    r4 = serve_stream("ata", stream.batched(4))
    assert r4.slots == 4 and r1.slots == 1
    np.testing.assert_array_equal(r4.latency, r1.latency)
    np.testing.assert_array_equal(r4.served, r1.served)
    np.testing.assert_array_equal(r4.shard_load, r1.shard_load)
    np.testing.assert_array_equal(r4.tenant_requests,
                                  r1.tenant_requests)
    np.testing.assert_array_equal(r4.tenant_hit_blocks,
                                  r1.tenant_hit_blocks)
    np.testing.assert_array_equal(r4.tenant_latency_sum,
                                  r1.tenant_latency_sum)
    # fewer, wider rounds: strictly fewer modeled cycles, higher
    # modeled throughput — the >= 1.5x acceptance bar at B=4
    assert r4.cycles < r1.cycles
    assert r4.requests_per_kcycle >= 1.5 * r1.requests_per_kcycle


def test_batched_stream_api():
    mix = ServingMix(("chat", "batch"))
    st = mix.make_stream(n_shards=4, rounds=32, seed=2)
    b = st.batched(4)
    assert b.slots == 4
    assert b.rounds == 32 and b.admission_rounds == 8
    np.testing.assert_array_equal(b.hashes, st.hashes)   # relabeling
    back = b.slot_sequential()
    assert back.slots == 1 and back.admission_rounds == 32
    with pytest.raises(ValueError):
        st.batched(5)        # 32 rows not divisible by 5
    with pytest.raises(ValueError):
        st.batched(0)
    with pytest.raises(ValueError):
        mix.make_stream(n_shards=4, rounds=32, seed=2, slots=99)


def test_make_stream_slots_widen_admission():
    """slots=B admits the B=1 winners in slot 0 plus the contenders a
    one-slot grid would have dropped; offered traffic is unchanged."""
    mix = ServingMix(("chat", "batch"))
    st1 = mix.make_stream(n_shards=4, rounds=48, seed=3)
    st2 = mix.make_stream(n_shards=4, rounds=48, seed=3, slots=2)
    assert st2.slots == 2 and st2.rounds == 96
    assert st2.admission_rounds == st1.rounds
    # slot 0 of every round is exactly the rotating-priority winner
    v2 = st2.valid.reshape(48, 2, 4)
    h2 = st2.hashes.reshape(48, 2, 4, -1)
    np.testing.assert_array_equal(v2[:, 0], st1.valid)
    np.testing.assert_array_equal(h2[:, 0], st1.hashes)
    # wider admission serves the dropped contenders too
    assert st2.n_requests > st1.n_requests
    # slots beyond the contender count stay empty (2 tenants, B=4)
    st4 = mix.make_stream(n_shards=4, rounds=48, seed=3, slots=4)
    assert st4.n_requests == st2.n_requests
    assert not st4.valid.reshape(48, 4, 4)[:, 2:].any()


def test_b1_matches_committed_baseline():
    """The engine reproduces the committed serving baseline's B=1 cell
    integer-for-integer (guards the packed-directory rewrite)."""
    import json
    import pathlib
    path = pathlib.Path(__file__).resolve().parents[1] \
        / "benchmarks" / "baselines" / "serving_rounds512.json"
    rep = json.loads(path.read_text())
    cell = next(c for c in rep["cells"]
                if (c["shards"], c["mix"], c["policy"],
                    c.get("slots", 1)) == (8, "chat+rag", "ata", 1))
    mix = ServingMix(("chat", "rag"), name="chat+rag")
    st = mix.make_stream(n_shards=8, rounds=cell["rounds"],
                         seed=rep["config"]["seed"])
    res = serve_stream("ata", st)
    assert st.n_requests == cell["requests"]
    assert res.local_hits == cell["local_hits"]
    assert res.remote_hits == cell["remote_hits"]
    assert res.recomputed_blocks == cell["recomputed_blocks"]
    assert res.probe_messages == cell["probe_messages"]
    assert res.hit_rate == pytest.approx(cell["hit_rate"], rel=1e-12)


# ---------------------------------------------------------------------------
# probe backends
# ---------------------------------------------------------------------------
def test_pallas_interpret_backend_matches_lax(stream, results):
    cfg = ServingConfig(probe_backend="pallas_interpret")
    res = serve_stream("ata", stream, cfg)
    _assert_matches(res, ref.run_stream("ata", ref.AtaCacheConfig(),
                                        stream))
    np.testing.assert_array_equal(res.latency, results["ata"].latency)


def test_bad_probe_backend_rejected():
    with pytest.raises(ValueError):
        ServingConfig(probe_backend="mosaic?")


# ---------------------------------------------------------------------------
# NoC pricing
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("noc", ("ideal", "crossbar", "ring"))
def test_noc_conservation_and_counter_stability(stream, results, noc):
    """Flit conservation holds per model, and pricing never perturbs
    the integer accounting (latency-only coupling)."""
    res = serve_stream("ata", stream, ServingConfig(noc=noc))
    assert res.noc_injected == pytest.approx(
        res.noc_delivered + res.noc_queued)
    assert res.noc_injected > 0          # remote fetches really priced
    _assert_matches(res, ref.run_stream("ata", ref.AtaCacheConfig(),
                                        stream))
    np.testing.assert_array_equal(res.served, results["ata"].served)


def test_ring_costs_more_latency_than_ideal(stream):
    """Hop distance adds delay on every remote fetch, so total modeled
    latency is strictly larger whenever remote traffic exists."""
    ideal = serve_stream("ata", stream, ServingConfig(noc="ideal"))
    ring = serve_stream("ata", stream, ServingConfig(noc="ring"))
    assert ideal.remote_fetch_blocks > 0
    assert float(ring.latency.sum()) > float(ideal.latency.sum())


# ---------------------------------------------------------------------------
# per-tenant attribution
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_tenant_attribution_sums_to_totals(stream, results, policy):
    res = results[policy]
    assert res.tenants == stream.tenants
    assert int(res.tenant_requests.sum()) == stream.n_requests
    assert int(res.tenant_blocks.sum()) \
        == int(stream.n_blocks[stream.valid].sum())
    assert int(res.tenant_hit_blocks.sum()) \
        == res.local_hits + res.remote_hits
    assert float(res.tenant_latency_sum.sum()) \
        == pytest.approx(float(res.latency.sum()), rel=1e-5)


def test_chat_outhits_batch_under_ata(results):
    """The high-sharing tenant reuses more of its blocks."""
    res = results["ata"]
    chat, batch = (res.tenant_hit_blocks / np.maximum(res.tenant_blocks,
                                                      1))
    assert chat > batch


# ---------------------------------------------------------------------------
# stream generator
# ---------------------------------------------------------------------------
def test_tenant_slots_are_hash_disjoint():
    """Slot striding keeps tenants in disjoint hash sub-spaces."""
    a = tenant_stream("chat", n_shards=4, rounds=32, seed=7, slot=0)
    b = tenant_stream("chat", n_shards=4, rounds=32, seed=7, slot=1)
    ha = set(np.unique(a.hashes[a.valid])) - {0}
    hb = set(np.unique(b.hashes[b.valid])) - {0}
    assert ha and hb and not (ha & hb)


def test_one_tenant_mix_is_the_solo_stream():
    """Deterministic twin of the hypothesis property: a 1-tenant mix
    carries exactly the solo tenant's arrays (slot 0, no offset)."""
    solo = tenant_stream("rag", n_shards=4, rounds=48, seed=5, slot=0)
    mix = ServingMix(("rag",)).make_stream(n_shards=4, rounds=48, seed=5)
    np.testing.assert_array_equal(mix.valid, solo.valid)
    np.testing.assert_array_equal(mix.hashes, solo.hashes)
    np.testing.assert_array_equal(mix.n_blocks, solo.n_blocks)


def test_burst_and_diurnal_modulate_arrivals():
    """batch's bursts push arrivals above its base rate in some rounds;
    rag's diurnal swing makes round occupancy non-uniform."""
    batch = tenant_stream("batch", n_shards=8, rounds=512, seed=0)
    from repro.core.trace.serving import TENANTS
    base = TENANTS["batch"].rate
    # bursts multiply the arrival rate for whole windows, so mean
    # occupancy sits well above the base rate a burst-free stream
    # would fluctuate around
    assert batch.valid.mean() > base + 0.1
    rag = tenant_stream("rag", n_shards=8, rounds=4096, seed=0)
    half = rag.valid.sum() // 2
    first = rag.valid[:2048].sum()
    assert abs(int(first) - int(half)) > 64   # phase asymmetry


# ---------------------------------------------------------------------------
# overflow headroom
# ---------------------------------------------------------------------------
def test_near_overflow_latency_accumulation(stream):
    """Planted near-overflow run: with a recompute cost of 2^20 cycles
    the latency sums blow far past int32/f32-carry range; the host
    float64/int64 accumulators must stay exact to the integer."""
    cfg = ServingConfig(lat_recompute=float(1 << 20))
    res = serve_stream("private", stream, cfg)
    # the plant is real: past 2^31 (and past exact-f32 at 2^24)
    total = res.local_hits + 4 * res.remote_hits \
        + (1 << 20) * res.recomputed_blocks
    assert total > 2 ** 31
    # private + ideal NoC: latency is a pure integer cost model, so
    # the per-tenant sums and the latency grid agree exactly
    assert int(res.tenant_latency_sum.sum()) == total
    assert int(np.sum(res.latency, dtype=np.float64)) == total
    assert res.tenant_latency_sum.dtype == np.float64
    assert res.cycles == float(np.sum(
        res.latency.max(axis=1), dtype=np.float64))


def test_headroom_guard_rejects_unsafe_costs(stream):
    """Config-time guard: per-request latency beyond f32 integer-exact
    range is refused instead of silently losing cycles."""
    with pytest.raises(ValueError, match="f32"):
        serve_stream("private", stream,
                     ServingConfig(lat_recompute=2.0 ** 24))


# ---------------------------------------------------------------------------
# compile budget
# ---------------------------------------------------------------------------
def test_one_executable_per_policy(stream):
    """The chunked replay compiles once per (policy, stream geometry,
    config) and reuses it across calls."""
    before = engine.compile_count()
    small = ServingMix(("chat",)).make_stream(n_shards=2, rounds=16)
    for _ in range(3):
        for p in SERVING_POLICIES:
            serve_stream(p, small)
    assert engine.compile_count() - before <= len(SERVING_POLICIES)


def test_one_executable_per_policy_backend_slots():
    """The executable cache keys on (policy, backend, B): replaying at
    several widths and round counts compiles exactly one chunk per
    key — the benchmark grid's compile budget."""
    mix = ServingMix(("chat", "batch"))
    streams = [mix.make_stream(n_shards=2, rounds=r, seed=9)
               for r in (16, 32)]      # different rounds, same chunk
    before = engine.compile_count()
    for _ in range(2):
        for st in streams:
            for p in SERVING_POLICIES:
                for b in (1, 2, 4):
                    serve_stream(p, st.batched(b))
    assert engine.compile_count() - before \
        <= len(SERVING_POLICIES) * 3


# ---------------------------------------------------------------------------
# the regression gate
# ---------------------------------------------------------------------------
def _serving_report(**over):
    cell = {"shards": 4, "mix": "chat+batch", "policy": "ata",
            "requests": 1000, "hit_rate": 0.4, "probe_messages": 0,
            "p99_latency": 500.0}
    cell.update(over)
    return {"kind": "serving", "schema": 1,
            "config": {"shards": [4], "rounds": 64},
            "cells": [cell], "headline": {}}


def test_compare_serving_identity_and_drift():
    from repro.core.report import compare_serving
    base = _serving_report()
    assert compare_serving(base, base) == []
    # probe messages gate exactly — off by one fails
    fails = compare_serving(base, _serving_report(probe_messages=1))
    assert any("probe-message" in f for f in fails)
    # hit rate within tolerance passes, beyond fails (both directions)
    assert compare_serving(base,
                           _serving_report(hit_rate=0.4001)) == []
    fails = compare_serving(base, _serving_report(hit_rate=0.45))
    assert any("hit-rate" in f for f in fails)
    # request-count drift means the stream itself changed
    fails = compare_serving(base, _serving_report(requests=999))
    assert any("request count" in f for f in fails)


def test_compare_serving_structural_failures():
    from repro.core.report import compare_serving
    base = _serving_report()
    missing = dict(base, cells=[])
    assert any("missing" in f for f in compare_serving(base, missing))
    other_cfg = dict(base, config={"shards": [8], "rounds": 64})
    assert any("config mismatch" in f
               for f in compare_serving(base, other_cfg))
    not_serving = dict(base, kind="simspeed")
    assert any("not a serving report" in f
               for f in compare_serving(base, not_serving))
    # p99 is gated only on opt-in
    moved = _serving_report(p99_latency=900.0)
    assert compare_serving(base, moved) == []
    fails = compare_serving(base, moved, latency_rtol=0.25)
    assert any("p99" in f for f in fails)


def _batched_report(model=3.4, wall=0.9, slots=4):
    rep = _serving_report()
    rep["headline"] = {"batched_model_speedup": model,
                       "batched_wall_speedup": wall,
                       "batched_slots": slots}
    return rep


def test_compare_serving_batched_speedup_gate():
    """The batched modeled-throughput ratio gates one-sided against
    the 1.5x absolute floor and the baseline minus batched_rtol."""
    from repro.core.report import compare_serving
    base = _batched_report(model=3.4)
    assert compare_serving(base, _batched_report(model=3.2)) == []
    assert compare_serving(base, _batched_report(model=9.9)) == []
    # relative drop beyond tolerance fails even above the floor
    fails = compare_serving(base, _batched_report(model=2.0))
    assert any("batched modeled speedup" in f for f in fails)
    # the absolute floor binds even when the baseline sits near it
    low = _batched_report(model=1.55)
    fails = compare_serving(low, _batched_report(model=1.45))
    assert any("batched modeled speedup" in f for f in fails)
    # a candidate that lost the headline entirely fails
    gone = _serving_report()
    fails = compare_serving(base, gone)
    assert any("missing" in f for f in fails)
    # wall-clock ratio gates only on opt-in (host-dependent)
    slow_wall = _batched_report(model=3.4, wall=0.4)
    assert compare_serving(base, slow_wall) == []
    fails = compare_serving(base, slow_wall, wall_rtol=0.25)
    assert any("wall speedup" in f for f in fails)
    # a baseline without the headline (schema 1) never gates it
    assert compare_serving(_serving_report(), gone) == []


def test_compare_serving_per_slot_cells():
    """Cells key on slots too; schema-1 cells default to B=1."""
    from repro.core.report import compare_serving
    b1 = _serving_report()                   # no "slots" key
    b1_explicit = _serving_report(slots=1)
    assert compare_serving(b1, b1_explicit) == []
    # a B=4 baseline cell must find its B=4 twin, not the B=1 cell
    base = dict(b1, cells=[_serving_report()["cells"][0],
                           _serving_report(slots=4)["cells"][0]])
    cand_missing = dict(b1, cells=[_serving_report()["cells"][0]])
    fails = compare_serving(base, cand_missing)
    assert any("missing" in f and "4" in f for f in fails)


def test_fig_serving_scale_report_shape(tmp_path):
    """The benchmark emits a gate-compatible kind=serving report with
    per-B cells and the batched-speedup headline."""
    from benchmarks import fig_serving_scale
    from repro.core.report import compare_serving
    mix = ServingMix(("chat", "batch"))
    out = tmp_path / "serving.json"
    rep = fig_serving_scale.run(rounds=ROUNDS, shards=(N_SHARDS,),
                                mixes=(mix,), seed=1,
                                out_json=str(out))
    assert out.exists()
    assert rep["kind"] == "serving"
    assert len(rep["cells"]) == len(SERVING_POLICIES) \
        * len(fig_serving_scale.SLOT_COUNTS)
    assert compare_serving(rep, rep) == []
    assert rep["headline"]["probes_filtered"] > 0
    assert rep["headline"]["batched_model_speedup"] >= 1.5
    # per-B cells share every counter (slot-order exactness) and the
    # B=1 cells reproduce the module fixtures
    by_key = {(c["policy"], c["slots"]): c for c in rep["cells"]}
    assert by_key[("ata", 1)]["probe_messages"] == 0
    assert by_key[("broadcast", 1)]["probe_messages"] > 0
    for p in SERVING_POLICIES:
        assert by_key[(p, 4)]["hit_rate"] == by_key[(p, 1)]["hit_rate"]
        assert by_key[(p, 4)]["probe_messages"] \
            == by_key[(p, 1)]["probe_messages"]
        assert by_key[(p, 4)]["requests_per_kcycle"] \
            > by_key[(p, 1)]["requests_per_kcycle"]
