"""Vectorized serving engine vs the retained numpy oracle.

The contract is *bit-exactness*: ``repro.serving.engine.serve_stream``
must reproduce the oracle's hit/probe/fetch accounting integer-for-
integer on the same :class:`~repro.core.trace.serving.RequestStream`,
for every serving policy, both on packed multi-request rounds and on
the sequentialized stream (one request per round — where round
semantics degenerate to the oracle's original one-at-a-time order).
On top of that: conservation invariants, probe-message bounds, probe-
backend equivalence, NoC pricing conservation, per-tenant attribution,
compile-count bounds, and the ``compare_serving`` regression gate.
"""
import numpy as np
import pytest

from repro.core.trace.serving import ServingMix, tenant_stream
from repro.serving import (SERVING_POLICIES, ServingConfig, engine, ref,
                           serve_stream)

N_SHARDS = 4
ROUNDS = 64


@pytest.fixture(scope="module")
def stream():
    # chat+batch: high- and low-sharing tenants with bursty arrivals,
    # past the cold-start transient at 4 shards x 64 rounds
    return ServingMix(("chat", "batch")).make_stream(
        n_shards=N_SHARDS, rounds=ROUNDS, seed=1)


@pytest.fixture(scope="module")
def results(stream):
    return {p: serve_stream(p, stream) for p in SERVING_POLICIES}


@pytest.fixture(scope="module")
def oracle(stream):
    return {p: ref.run_stream(p, ref.AtaCacheConfig(), stream)
            for p in SERVING_POLICIES}


def _assert_matches(res, st):
    assert res.local_hits == st.local_hits
    assert res.remote_hits == st.remote_hits
    assert res.recomputed_blocks == st.recomputed_blocks
    assert res.probe_messages == st.probe_messages
    assert res.remote_fetch_blocks == st.remote_fetch_blocks
    assert res.directory_sync_entries == st.directory_sync_entries
    np.testing.assert_array_equal(res.shard_load, st.shard_load)


# ---------------------------------------------------------------------------
# oracle equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_engine_matches_oracle_packed(results, oracle, policy):
    """Full rounds (up to one request per shard) — bit-exact."""
    _assert_matches(results[policy], oracle[policy])


@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_engine_matches_oracle_sequential(stream, policy):
    """One request per round: the oracle's original sequential order."""
    seq = stream.sequential()
    res = serve_stream(policy, seq)
    st = ref.run_stream(policy, ref.AtaCacheConfig(), seq)
    _assert_matches(res, st)
    # and sequentialization preserves the request population exactly
    assert seq.n_requests == stream.n_requests


def test_oracle_broadcast_is_legacy_remote(stream):
    """`broadcast` is the legacy oracle's `remote` policy by alias."""
    a = ref.run_stream("broadcast", ref.AtaCacheConfig(), stream)
    b = ref.run_stream("remote", ref.AtaCacheConfig(), stream)
    assert (a.local_hits, a.remote_hits, a.probe_messages) \
        == (b.local_hits, b.remote_hits, b.probe_messages)


def test_oracle_rejects_engineless_policies(stream):
    with pytest.raises(ValueError):
        ref.run_stream("decoupled", ref.AtaCacheConfig(), stream)
    with pytest.raises(ValueError):
        serve_stream("decoupled", stream)


# ---------------------------------------------------------------------------
# conservation + bounds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_block_conservation(stream, results, policy):
    """Every valid block is served exactly once: hit or recomputed."""
    res = results[policy]
    total_blocks = int(stream.n_blocks[stream.valid].sum())
    assert (res.local_hits + res.remote_hits + res.recomputed_blocks
            == total_blocks)
    assert res.n_requests == stream.n_requests
    assert int(res.served.sum()) == stream.n_requests


def test_probe_message_bounds(stream, results):
    """private/ata never probe; broadcast probes <= blocks x (C-1)."""
    assert results["private"].probe_messages == 0
    assert results["ata"].probe_messages == 0
    total_blocks = int(stream.n_blocks[stream.valid].sum())
    bcast = results["broadcast"].probe_messages
    assert 0 < bcast <= total_blocks * (N_SHARDS - 1)


def test_ata_replicates_and_syncs(results):
    """ata fetches remotely and fills locally (Fig 7a); every newly
    sealed block is a directory delta all-gather entry; broadcast
    probes instead of syncing."""
    ata = results["ata"]
    assert ata.remote_fetch_blocks > 0
    assert ata.directory_sync_entries == ata.recomputed_blocks
    assert results["broadcast"].directory_sync_entries == 0
    assert results["private"].remote_fetch_blocks == 0


def test_hit_rate_ordering(results):
    """Sharing beats private; zero-cost visibility beats probing."""
    assert results["ata"].hit_rate >= results["broadcast"].hit_rate - 1e-9
    assert results["broadcast"].hit_rate > results["private"].hit_rate


# ---------------------------------------------------------------------------
# probe backends
# ---------------------------------------------------------------------------
def test_pallas_interpret_backend_matches_lax(stream, results):
    cfg = ServingConfig(probe_backend="pallas_interpret")
    res = serve_stream("ata", stream, cfg)
    _assert_matches(res, ref.run_stream("ata", ref.AtaCacheConfig(),
                                        stream))
    np.testing.assert_array_equal(res.latency, results["ata"].latency)


def test_bad_probe_backend_rejected():
    with pytest.raises(ValueError):
        ServingConfig(probe_backend="mosaic?")


# ---------------------------------------------------------------------------
# NoC pricing
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("noc", ("ideal", "crossbar", "ring"))
def test_noc_conservation_and_counter_stability(stream, results, noc):
    """Flit conservation holds per model, and pricing never perturbs
    the integer accounting (latency-only coupling)."""
    res = serve_stream("ata", stream, ServingConfig(noc=noc))
    assert res.noc_injected == pytest.approx(
        res.noc_delivered + res.noc_queued)
    assert res.noc_injected > 0          # remote fetches really priced
    _assert_matches(res, ref.run_stream("ata", ref.AtaCacheConfig(),
                                        stream))
    np.testing.assert_array_equal(res.served, results["ata"].served)


def test_ring_costs_more_latency_than_ideal(stream):
    """Hop distance adds delay on every remote fetch, so total modeled
    latency is strictly larger whenever remote traffic exists."""
    ideal = serve_stream("ata", stream, ServingConfig(noc="ideal"))
    ring = serve_stream("ata", stream, ServingConfig(noc="ring"))
    assert ideal.remote_fetch_blocks > 0
    assert float(ring.latency.sum()) > float(ideal.latency.sum())


# ---------------------------------------------------------------------------
# per-tenant attribution
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_tenant_attribution_sums_to_totals(stream, results, policy):
    res = results[policy]
    assert res.tenants == stream.tenants
    assert int(res.tenant_requests.sum()) == stream.n_requests
    assert int(res.tenant_blocks.sum()) \
        == int(stream.n_blocks[stream.valid].sum())
    assert int(res.tenant_hit_blocks.sum()) \
        == res.local_hits + res.remote_hits
    assert float(res.tenant_latency_sum.sum()) \
        == pytest.approx(float(res.latency.sum()), rel=1e-5)


def test_chat_outhits_batch_under_ata(results):
    """The high-sharing tenant reuses more of its blocks."""
    res = results["ata"]
    chat, batch = (res.tenant_hit_blocks / np.maximum(res.tenant_blocks,
                                                      1))
    assert chat > batch


# ---------------------------------------------------------------------------
# stream generator
# ---------------------------------------------------------------------------
def test_tenant_slots_are_hash_disjoint():
    """Slot striding keeps tenants in disjoint hash sub-spaces."""
    a = tenant_stream("chat", n_shards=4, rounds=32, seed=7, slot=0)
    b = tenant_stream("chat", n_shards=4, rounds=32, seed=7, slot=1)
    ha = set(np.unique(a.hashes[a.valid])) - {0}
    hb = set(np.unique(b.hashes[b.valid])) - {0}
    assert ha and hb and not (ha & hb)


def test_one_tenant_mix_is_the_solo_stream():
    """Deterministic twin of the hypothesis property: a 1-tenant mix
    carries exactly the solo tenant's arrays (slot 0, no offset)."""
    solo = tenant_stream("rag", n_shards=4, rounds=48, seed=5, slot=0)
    mix = ServingMix(("rag",)).make_stream(n_shards=4, rounds=48, seed=5)
    np.testing.assert_array_equal(mix.valid, solo.valid)
    np.testing.assert_array_equal(mix.hashes, solo.hashes)
    np.testing.assert_array_equal(mix.n_blocks, solo.n_blocks)


def test_burst_and_diurnal_modulate_arrivals():
    """batch's bursts push arrivals above its base rate in some rounds;
    rag's diurnal swing makes round occupancy non-uniform."""
    batch = tenant_stream("batch", n_shards=8, rounds=512, seed=0)
    from repro.core.trace.serving import TENANTS
    base = TENANTS["batch"].rate
    # bursts multiply the arrival rate for whole windows, so mean
    # occupancy sits well above the base rate a burst-free stream
    # would fluctuate around
    assert batch.valid.mean() > base + 0.1
    rag = tenant_stream("rag", n_shards=8, rounds=4096, seed=0)
    half = rag.valid.sum() // 2
    first = rag.valid[:2048].sum()
    assert abs(int(first) - int(half)) > 64   # phase asymmetry


# ---------------------------------------------------------------------------
# compile budget
# ---------------------------------------------------------------------------
def test_one_executable_per_policy(stream):
    """The scan jits once per (policy, stream shape, config)."""
    before = engine.compile_count()
    small = ServingMix(("chat",)).make_stream(n_shards=2, rounds=16)
    for _ in range(3):
        for p in SERVING_POLICIES:
            serve_stream(p, small)
    assert engine.compile_count() - before <= len(SERVING_POLICIES)


# ---------------------------------------------------------------------------
# the regression gate
# ---------------------------------------------------------------------------
def _serving_report(**over):
    cell = {"shards": 4, "mix": "chat+batch", "policy": "ata",
            "requests": 1000, "hit_rate": 0.4, "probe_messages": 0,
            "p99_latency": 500.0}
    cell.update(over)
    return {"kind": "serving", "schema": 1,
            "config": {"shards": [4], "rounds": 64},
            "cells": [cell], "headline": {}}


def test_compare_serving_identity_and_drift():
    from repro.core.report import compare_serving
    base = _serving_report()
    assert compare_serving(base, base) == []
    # probe messages gate exactly — off by one fails
    fails = compare_serving(base, _serving_report(probe_messages=1))
    assert any("probe-message" in f for f in fails)
    # hit rate within tolerance passes, beyond fails (both directions)
    assert compare_serving(base,
                           _serving_report(hit_rate=0.4001)) == []
    fails = compare_serving(base, _serving_report(hit_rate=0.45))
    assert any("hit-rate" in f for f in fails)
    # request-count drift means the stream itself changed
    fails = compare_serving(base, _serving_report(requests=999))
    assert any("request count" in f for f in fails)


def test_compare_serving_structural_failures():
    from repro.core.report import compare_serving
    base = _serving_report()
    missing = dict(base, cells=[])
    assert any("missing" in f for f in compare_serving(base, missing))
    other_cfg = dict(base, config={"shards": [8], "rounds": 64})
    assert any("config mismatch" in f
               for f in compare_serving(base, other_cfg))
    not_serving = dict(base, kind="simspeed")
    assert any("not a serving report" in f
               for f in compare_serving(base, not_serving))
    # p99 is gated only on opt-in
    moved = _serving_report(p99_latency=900.0)
    assert compare_serving(base, moved) == []
    fails = compare_serving(base, moved, latency_rtol=0.25)
    assert any("p99" in f for f in fails)


def test_fig_serving_scale_report_shape(tmp_path):
    """The benchmark emits a gate-compatible kind=serving report."""
    from benchmarks import fig_serving_scale
    from repro.core.report import compare_serving
    mix = ServingMix(("chat", "batch"))
    out = tmp_path / "serving.json"
    rep = fig_serving_scale.run(rounds=ROUNDS, shards=(N_SHARDS,),
                                mixes=(mix,), seed=1,
                                out_json=str(out))
    assert out.exists()
    assert rep["kind"] == "serving"
    assert len(rep["cells"]) == len(SERVING_POLICIES)
    assert compare_serving(rep, rep) == []
    assert rep["headline"]["probes_filtered"] > 0
    # cells reproduce the module fixtures (same stream, same engine)
    by_pol = {c["policy"]: c for c in rep["cells"]}
    assert by_pol["ata"]["probe_messages"] == 0
    assert by_pol["broadcast"]["probe_messages"] > 0
