"""Sharding-rule coverage and multi-device integration (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.sharding.rules import make_rules, param_axes

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_cover_every_full_config_param(arch):
    """Every parameter of every *full* config resolves to axis rules of
    the right rank (eval_shape: no allocation)."""
    from repro.models import transformer as T
    cfg = get_config(arch)
    params = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    axes = param_axes(params)       # raises if any param is uncovered
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda a: isinstance(a, tuple))
    assert len(flat_p) == len(flat_a)
    for p, a in zip(flat_p, flat_a):
        assert p.ndim == len(a)


def _run_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=900)


def test_sharded_train_step_runs_on_8_devices():
    r = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch import specs as SP
        from repro.launch.mesh import make_test_mesh
        from repro.optim.adamw import AdamWConfig
        from repro.sharding.compat import activate_mesh
        from repro.sharding.rules import make_rules, rules_context
        from repro.train.step import init_train_state, make_train_step
        cfg = get_smoke_config("qwen3-0.6b")
        mesh = make_test_mesh(4, 2)
        rules = make_rules(cfg, mesh, batch_size=8)
        with rules_context(mesh, rules), activate_mesh(mesh):
            state = init_train_state(jax.random.PRNGKey(0), cfg)
            st_sh = SP.train_state_shardings(
                jax.eval_shape(lambda: state), cfg, mesh, rules)
            state = jax.device_put(state, st_sh)
            step = jax.jit(make_train_step(cfg, AdamWConfig()),
                           in_shardings=(st_sh, None),
                           out_shardings=(st_sh, None))
            tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64),
                                        0, cfg.vocab_size)
            state, m = step(state, {"tokens": tokens, "labels": tokens})
            assert np.isfinite(float(m["loss"]))
        print("SHARDED_OK", float(m["loss"]))
    """)
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr


def test_dp_profile_matches_tp_profile_loss():
    """Same step, two parallelism profiles -> same loss (numerics)."""
    r = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch import specs as SP
        from repro.launch.mesh import make_test_mesh
        from repro.optim.adamw import AdamWConfig
        from repro.sharding.compat import activate_mesh
        from repro.sharding.rules import make_rules, rules_context
        from repro.train.step import init_train_state, make_train_step
        cfg = get_smoke_config("qwen3-0.6b")
        mesh = make_test_mesh(4, 2)
        losses = []
        for profile in ("tp", "dp"):
            rules = make_rules(cfg, mesh, batch_size=8, profile=profile)
            with rules_context(mesh, rules), activate_mesh(mesh):
                state = init_train_state(jax.random.PRNGKey(0), cfg)
                st_sh = SP.train_state_shardings(
                    jax.eval_shape(lambda: state), cfg, mesh, rules)
                state = jax.device_put(state, st_sh)
                step = jax.jit(make_train_step(cfg, AdamWConfig()),
                               in_shardings=(st_sh, None),
                               out_shardings=(st_sh, None))
                tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                            0, cfg.vocab_size)
                _, m = step(state, {"tokens": tokens, "labels": tokens})
                losses.append(float(m["loss"]))
        assert abs(losses[0] - losses[1]) < 1e-3, losses
        print("PROFILES_OK", losses)
    """)
    assert "PROFILES_OK" in r.stdout, r.stdout + r.stderr


def test_compressed_allreduce_on_8_devices():
    """int8 error-feedback all-reduce inside shard_map: mean preserved
    within quantization tolerance and error buffers carry the residual."""
    r = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import all_reduce_compressed
        from repro.sharding.compat import make_mesh, shard_map
        mesh = make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        e = jnp.zeros((8, 64))
        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data")))
        def f(gs, es):
            r, ne = all_reduce_compressed(gs, es, "data")
            return r, ne
        red, nerr = f(g, e)
        exact = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
        err = float(jnp.abs(red - exact).max())
        scale = float(jnp.abs(g).max()) / 127.0
        assert err < 2 * scale, (err, scale)
        print("COMPRESS_OK", err)
    """)
    assert "COMPRESS_OK" in r.stdout, r.stdout + r.stderr


def test_dryrun_cell_results_exist_and_fit():
    """The committed dry-run artifacts cover all 40x2 cells."""
    d = os.path.join(ROOT, "results", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("dry-run artifacts not generated yet")
    cells = [json.load(open(os.path.join(d, f))) for f in os.listdir(d)
             if f.endswith(".json")]
    assert len(cells) == 80
    bad = [c for c in cells if c["status"] not in ("ok", "skipped")]
    assert not bad, [(c['arch'], c['shape']) for c in bad]
    skips = [c for c in cells if c["status"] == "skipped"]
    assert len(skips) == 16      # long_500k x 8 full-attention archs x 2
