"""WorkloadMix composition, MixResult fairness math, the run_mixes
driver riding the sweep grid, and the mix section of the sensitivity
report + its schema-versioned regression gate."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import (APPS, PAPER_GEOMETRY, AppStats, SimResult,
                        SweepGrid, SweepPoint, WorkloadMix, run_mixes,
                        simulate)
from repro.core import report as sensitivity
from repro.core.metrics import MixResult
from repro.core.trace.mix import APP_STRIDE


def same_result(a, b):
    return all(x == y or (x != x and y != y)
               for x, y in zip(tuple(a), tuple(b)))


# ---------------------------------------------------------------------------
# core assignment layouts
# ---------------------------------------------------------------------------
def test_partitioned_assignment_is_contiguous_blocks():
    mix = WorkloadMix(apps=("cfd", "HS3D"))
    assign = mix.core_assignment(30)
    assert assign.tolist() == [0] * 15 + [1] * 15


def test_interleaved_assignment_deals_round_robin():
    mix = WorkloadMix(apps=("cfd", "HS3D"), layout="interleaved")
    assign = mix.core_assignment(6)
    assert assign.tolist() == [0, 1, 0, 1, 0, 1]
    # asymmetric shares: round-robin until the small share is spent
    mix = WorkloadMix(apps=("cfd", "HS3D"), shares=(4, 2),
                      layout="interleaved")
    assert mix.core_assignment(6).tolist() == [0, 1, 0, 1, 0, 0]


def test_asymmetric_shares_and_share_validation():
    mix = WorkloadMix(apps=("cfd", "HS3D"), shares=(20, 10))
    assert mix.core_assignment(30).tolist() == [0] * 20 + [1] * 10
    with pytest.raises(ValueError, match="sum to n_cores"):
        WorkloadMix(apps=("cfd", "HS3D"), shares=(20, 20)) \
            .core_assignment(30)
    with pytest.raises(ValueError, match=">= 1 core"):
        WorkloadMix(apps=("cfd", "HS3D"), shares=(30, 0)) \
            .core_assignment(30)
    # equal split distributes the remainder to early slots
    assert WorkloadMix(apps=("cfd", "HS3D", "lud")) \
        .resolve_shares(10) == (4, 3, 3)


def test_mix_spec_validation():
    with pytest.raises(ValueError, match="at least one app"):
        WorkloadMix(apps=())
    with pytest.raises(ValueError, match="layout"):
        WorkloadMix(apps=("cfd",), layout="striped")
    with pytest.raises(ValueError, match="unknown app"):
        WorkloadMix(apps=("nope",))
    with pytest.raises(ValueError, match="one core count per app"):
        WorkloadMix(apps=("cfd", "HS3D"), shares=(30,))
    with pytest.raises(ValueError, match="one kernel per app"):
        WorkloadMix(apps=("cfd", "HS3D"), kernels=(0,))


def test_mix_id_is_stable_and_descriptive():
    assert WorkloadMix(apps=("cfd", "HS3D")).mix_id == "cfd+HS3D"
    m = WorkloadMix(apps=("cfd", "HS3D"), shares=(20, 10),
                    layout="interleaved", phase_rounds=7)
    assert m.mix_id == "cfd+HS3D@20,10|interleaved|ph7"
    assert WorkloadMix(apps=("cfd",), name="solo").mix_id == "solo"


# ---------------------------------------------------------------------------
# address-space slicing + phase stagger
# ---------------------------------------------------------------------------
def test_mix_slots_never_falsely_share_lines():
    mix = WorkloadMix(apps=("cfd", "cfd"), rounds=64)   # same app twice!
    tr = mix.compose(30)
    assign = tr.core_app
    a0 = tr.addr[:, assign == 0, :]
    a1 = tr.addr[:, assign == 1, :]
    assert a0.max() < APP_STRIDE                  # slot 0: original slice
    assert APP_STRIDE <= a1.min()                 # slot 1: offset slice
    assert a1.max() < 2 * APP_STRIDE
    # same app, distinct slots: different seeds, not a shifted copy
    assert not np.array_equal(a0, a1 - APP_STRIDE)


def test_phase_stagger_rotates_component_rounds():
    plain = WorkloadMix(apps=("cfd", "HS3D"), rounds=64)
    phased = dataclasses.replace(plain, phase_rounds=16)
    t0, t1 = plain.compose(30), phased.compose(30)
    cols = t0.core_app == 1
    np.testing.assert_array_equal(
        t1.addr[:, cols, :], np.roll(t0.addr[:, cols, :], 16, axis=0))
    # slot 0 is the phase anchor
    np.testing.assert_array_equal(t1.addr[:, ~cols, :],
                                  t0.addr[:, ~cols, :])


def test_component_traces_are_the_solo_baselines():
    """Solo baselines expose each core to byte-identical addresses as
    the composed mix — slowdown is pure interference."""
    mix = WorkloadMix(apps=("cfd", "HS3D"), rounds=64)
    comps = mix.component_traces(30)
    tr = mix.compose(30)
    for slot, comp in enumerate(comps):
        cols = tr.core_app == slot
        np.testing.assert_array_equal(tr.addr[:, cols, :],
                                      comp.addr[:, cols, :])


# ---------------------------------------------------------------------------
# MixResult fairness math (synthetic inputs with known answers)
# ---------------------------------------------------------------------------
def _sim(per_app, ipc=10.0):
    return SimResult(ipc=ipc, l1_latency=30.0, local_hit_rate=0.5,
                     remote_hit_rate=0.0, l1_hit_rate=0.5,
                     l2_accesses=1.0, dram_accesses=1.0, noc_flits=1.0,
                     cycles=100.0, instructions=1000.0,
                     per_app=tuple(per_app))


def _app(app, cores, ipc):
    return AppStats(app=app, cores=cores, instructions=ipc * 100.0,
                    cycles=100.0, requests=400.0, local_hits=100.0,
                    remote_hits=50.0, l1_lat_sum=300.0, l1_lat_n=10.0)


def test_mix_result_fairness_math():
    # app0: 10 cores at shared ipc 20 (2/core); solo 90 over 30 cores
    #   (3/core) -> slowdown 1.5
    # app1: 20 cores at shared ipc 40 (2/core); solo 60 over 30 cores
    #   (2/core) -> slowdown 1.0
    mr = MixResult(
        mix=WorkloadMix(apps=("cfd", "HS3D"), shares=(10, 20)),
        arch="ata",
        shared=_sim([_app(0, 10, 20.0), _app(1, 20, 40.0)]),
        solo=[_sim([_app(0, 30, 90.0)], ipc=90.0),
              _sim([_app(1, 30, 60.0)], ipc=60.0)])
    assert mr.n_cores == 30
    assert mr.slowdowns == pytest.approx([1.5, 1.0])
    assert mr.weighted_speedup == pytest.approx(1 / 1.5 + 1.0)
    assert mr.unfairness == pytest.approx(1.5)
    assert mr.per_app_ipc == pytest.approx([20.0, 40.0])
    assert mr.per_app_l1_hit_rate == pytest.approx([150 / 400] * 2)


def test_app_stats_derived_rates():
    a = _app(0, 10, 20.0)
    assert a.ipc == pytest.approx(20.0)
    assert a.local_hit_rate == pytest.approx(0.25)
    assert a.l1_hit_rate == pytest.approx(0.375)
    assert a.l1_latency == pytest.approx(30.0)
    starved = a._replace(l1_lat_n=0.0)
    assert np.isnan(starved.l1_latency)


# ---------------------------------------------------------------------------
# run_mixes rides the grid: bit-exact, budgeted executables
# ---------------------------------------------------------------------------
def test_run_mixes_bit_exact_and_budgeted():
    mixes = [WorkloadMix(apps=("cfd", "HS3D")),
             WorkloadMix(apps=("HS3D", "cfd"))]   # same shape, reversed
    run = run_mixes(mixes, archs=("private", "ata"), rounds=96)
    # 2 dataflow groups x {mix kind, solo kind} — same-shape mixes
    # share buckets, no per-mix recompilation
    assert run.report.n_executables <= 4, run.report
    for mix in (dataclasses.replace(m, rounds=96) for m in mixes):
        shared_tr = mix.compose(PAPER_GEOMETRY.n_cores)
        comps = mix.component_traces(PAPER_GEOMETRY.n_cores)
        for arch in ("private", "ata"):
            mr = run.results[mix.mix_id][arch]
            assert same_result(mr.shared, simulate(arch, shared_tr))
            for comp, solo in zip(comps, mr.solo):
                assert same_result(solo, simulate(arch, comp))
            assert 0 < mr.weighted_speedup <= 2.5
            assert mr.unfairness >= 1.0


def test_run_mixes_rejects_duplicate_ids():
    with pytest.raises(ValueError, match="duplicate mix ids"):
        run_mixes([WorkloadMix(apps=("cfd", "HS3D")),
                   WorkloadMix(apps=("cfd", "HS3D"))],
                  archs=("private",), rounds=32)


def test_mix_points_are_ordinary_sweep_grid_points():
    """A mix trace drops into SweepGrid next to solo traces and stacked
    families keep their executables."""
    from repro.core import make_trace
    mix = WorkloadMix(apps=("cfd", "HS3D"), rounds=96).compose(30)
    tr = make_trace(dataclasses.replace(APPS["cfd"], rounds=96))
    pts = [SweepPoint(a, PAPER_GEOMETRY, t)
           for a in ("ata", "ata_fifo") for t in (tr, mix)]
    grid = SweepGrid.from_points(pts)
    run = grid.run()
    assert run.report.n_executables == 2   # one family x 2 trace kinds
    for pt, r in zip(grid.points, run.results):
        assert same_result(r, simulate(pt.arch, pt.trace))


# ---------------------------------------------------------------------------
# fig_mix_fairness benchmark smoke
# ---------------------------------------------------------------------------
def test_fig_mix_fairness_smoke(capsys):
    from benchmarks import fig_mix_fairness
    out = fig_mix_fairness.run(rounds=48,
                               pairings=(("cfd", "HS3D"),),
                               archs=("private", "ata"))
    assert ("cfd+HS3D", "ata") in out and ("cfd+HS3D", "private") in out
    assert ("cfd+HS3D", "ata_vs_private") in out
    printed = capsys.readouterr().out
    assert "fig_mix.cfd+HS3D.ata.weighted_speedup" in printed
    assert "fig_mix.cfd+HS3D.ata.unfairness" in printed


def test_fig_mix_fairness_covers_three_app_mix(capsys):
    """The default mix set goes beyond pairs: a 3-app locality point
    rides the same figure/report surfaces (WS ideal = 3)."""
    from benchmarks import fig_mix_fairness
    trio = ("cfd", "b+tree", "HS3D")
    assert trio in sensitivity.MIX_PAIRINGS
    out = fig_mix_fairness.run(rounds=48, pairings=(trio,),
                               archs=("private", "ata"))
    mid = "cfd+b+tree+HS3D"
    assert (mid, "ata_vs_private") in out
    run = sensitivity.mix_grid_run((trio,), ("ata",), rounds=48)
    mr = run.results[mid]["ata"]
    assert len(mr.per_app_ipc) == 3
    assert len(mr.slowdowns) == 3
    assert 0.0 < mr.weighted_speedup <= 3.0
    assert mr.unfairness >= 1.0
    # the report's mix section carries the 3-app cell unchanged
    section = sensitivity.run_mix_sensitivity((trio,), ("ata",),
                                              rounds=48, mix_run=run)
    cell = next(c for c in section["cells"] if c["mix"] == mid)
    assert cell["weighted_speedup"] == pytest.approx(mr.weighted_speedup)
    assert len(cell["per_app_ipc"]) == 3


def test_fig_mix_fairness_reuses_shared_grid_run(capsys):
    """--report-json path: one mix_grid_run feeds figure + report."""
    from benchmarks import fig_mix_fairness
    pairings = (("cfd", "HS3D"),)
    shared = sensitivity.mix_grid_run(pairings, ("private", "ata"),
                                      rounds=48)
    out = fig_mix_fairness.run(rounds=48, pairings=pairings,
                               archs=("private", "ata"), mix_run=shared)
    mr = shared.results["cfd+HS3D"]["ata"]
    assert out[("cfd+HS3D", "ata")] == mr.weighted_speedup
    rep_section = sensitivity.run_mix_sensitivity(
        pairings, ("private", "ata"), rounds=48, mix_run=shared)
    cell = next(c for c in rep_section["cells"] if c["arch"] == "ata")
    assert cell["weighted_speedup"] \
        == pytest.approx(mr.weighted_speedup)


# ---------------------------------------------------------------------------
# sensitivity report: mix section + schema-versioned gate
# ---------------------------------------------------------------------------
KNOBS = {"hide": (5.0, 10.0)}


@pytest.fixture(scope="module")
def v2_report():
    return sensitivity.run_sensitivity(
        app="cfd", archs=("private", "ata"), knobs=KNOBS,
        kernels_per_app=1, rounds=64,
        mix_pairings=(("cfd", "HS3D"),))


def test_report_mix_section_structure(v2_report, tmp_path):
    rep = v2_report
    # a mix-without-noc report tags (and gates as) schema 2; only
    # reports also carrying the topology section claim SCHEMA_VERSION
    assert rep["schema"] == 2 < sensitivity.SCHEMA_VERSION
    mix = rep["mix"]
    assert {c["arch"] for c in mix["cells"]} \
        == set(sensitivity.MIX_ARCHS)
    for cell in mix["cells"]:
        assert cell["mix"] == "cfd+HS3D"
        assert cell["weighted_speedup"] > 0
        assert cell["unfairness"] >= 1.0
        assert len(cell["per_app_ipc"]) == 2
    # solo sweep accounting is untouched by the mix section existing
    assert mix["sweep"]["n_executables"] > 0
    assert rep["sweep"]["n_executables"] > 0
    md_path = sensitivity.write_report(str(tmp_path / "rep.json"), rep)
    md = open(md_path).read()
    assert "Multi-tenant fairness" in md
    assert "| cfd+HS3D | ata |" in md
    again = sensitivity.load_report(str(tmp_path / "rep.json"))
    assert again == json.loads(json.dumps(rep))


def test_gate_tolerates_newer_schema_with_mix_section(v2_report):
    rep = v2_report
    v1 = json.loads(json.dumps(rep))
    del v1["mix"]
    v1["schema"] = 1
    # schema-1 baseline vs schema-2 candidate: solo cells gate, the new
    # mix section is tolerated instead of failing on unknown keys
    assert sensitivity.compare_reports(v1, rep) == []
    # downgrades are not comparable
    fails = sensitivity.compare_reports(rep, v1)
    assert len(fails) == 1 and "schema mismatch" in fails[0]


def test_gate_flags_mix_drift_and_executable_growth(v2_report):
    rep = v2_report
    assert sensitivity.compare_reports(rep, rep) == []
    drift = json.loads(json.dumps(rep))
    drift["mix"]["cells"][0]["weighted_speedup"] *= 1.3
    fails = sensitivity.compare_reports(rep, drift)
    assert len(fails) == 1 and "weighted-speedup drift" in fails[0]
    grown = json.loads(json.dumps(rep))
    grown["mix"]["sweep"]["n_executables"] += 1
    fails = sensitivity.compare_reports(rep, grown)
    assert len(fails) == 1 and "mix executable count grew" in fails[0]
    missing = json.loads(json.dumps(rep))
    del missing["mix"]
    fails = sensitivity.compare_reports(rep, missing)
    assert len(fails) == 1 and "mix section missing" in fails[0]
