"""Observability stack: zero-cost-off, conservation, exact quantiles.

The telemetry contract has four legs, each tested here:

* **zero cost when off** — ``telemetry=None`` (the default) produces
  bit-identical results to the pre-telemetry code paths (the committed
  architecture goldens still hold with telemetry *on*, and turning it
  on/off never moves a counter), and the executable caches only grow
  when a telemetry config is actually passed;
* **conservation** — every windowed counter series sums exactly (no
  tolerance) to its ``SimResult`` / ``ServeResult`` total, across the
  policy zoo x NoC models and the serving policies x admission widths;
* **exact quantiles** — the serving latency histogram reproduces
  ``np.percentile`` over the materialized per-request latencies bit
  for bit (integral cost model), and the simulator's log2-bucketed
  variant is a conservative upper bound;
* **exporters** — Perfetto traces (generated and the committed smoke
  baseline) validate against the Chrome-trace-event schema, run
  manifests attach to all report kinds, and re-binned timelines are
  invariant to the capture window (hypothesis property below).
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import PAPER_GEOMETRY, APPS, TelemetryConfig, make_trace
from repro.core import simulate
from repro.core.telemetry import (hist_quantile, log2_bucket,
                                  serving_hist_bins)
from repro.core.trace.serving import ServingMix
from repro.obs import ConservationError, validate_trace
from repro.obs.perfetto import trace_events, write_trace
from repro.serving import SERVING_POLICIES, ServingConfig, engine, \
    serve_stream

ROUNDS = 96          # divisible by the default window (32)
TEL = TelemetryConfig(window=32)


def _trace(app="cfd", rounds=ROUNDS):
    return make_trace(dataclasses.replace(APPS[app], rounds=rounds),
                      kernel=1)


@pytest.fixture(scope="module")
def stream():
    return ServingMix(("chat", "batch")).make_stream(
        n_shards=4, rounds=64, seed=1)


# ---------------------------------------------------------------------------
# zero cost when off: bit-exactness against the uninstrumented path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("noc", ["ideal", "crossbar"])
def test_sim_result_identical_with_telemetry_on(noc):
    """The window restructuring preserves the per-round op sequence:
    every SimResult field is bit-equal with telemetry on vs off."""
    tr = _trace()
    base = simulate("ata", tr, noc=noc)
    res, tl = simulate("ata", tr, noc=noc, telemetry=TEL)
    assert base == res                      # NamedTuple: full compare
    assert tl.rounds == ROUNDS and tl.n_windows == ROUNDS // TEL.window


def test_sim_telemetry_on_still_matches_committed_golden():
    """Transitivity made explicit: the instrumented run reproduces the
    committed pre-refactor golden numbers, not just the current code."""
    from test_arch_registry import GOLDEN, INTEGRAL_FIELDS
    res, _ = simulate("ata", _trace("cfd", 192), telemetry=TelemetryConfig(window=64))
    for field, want in GOLDEN[("cfd", "ata")].items():
        got = getattr(res, field)
        if field in INTEGRAL_FIELDS:
            assert got == want, field
        else:
            assert got == pytest.approx(want, rel=1e-12), field


@pytest.mark.parametrize("b", [1, 4])
def test_serving_result_identical_with_telemetry_on(stream, b):
    base = serve_stream("ata", stream.batched(b))
    res, tl = serve_stream("ata", stream.batched(b), telemetry=TEL)
    assert base.local_hits == res.local_hits
    assert base.remote_hits == res.remote_hits
    assert base.recomputed_blocks == res.recomputed_blocks
    assert base.probe_messages == res.probe_messages
    assert base.cycles == res.cycles
    np.testing.assert_array_equal(base.latency, res.latency)
    np.testing.assert_array_equal(base.served, res.served)
    np.testing.assert_array_equal(base.shard_load, res.shard_load)
    assert base.lat_hist is None            # off: no histogram carry
    assert res.lat_hist is not None and res.hist_exact


def test_serving_off_path_compiles_nothing_new(stream):
    # a config no other test (or fig_serving_scale's default capture)
    # uses, so the cache-growth accounting below is unambiguous even
    # when the whole suite shares one process-wide executable cache
    tel = TelemetryConfig(window=16, sim_hist_bins=8)
    serve_stream("broadcast", stream)       # ensure cached
    before = engine.compile_count()
    serve_stream("broadcast", stream)
    assert engine.compile_count() == before  # same executable reused
    serve_stream("broadcast", stream, telemetry=tel)
    assert engine.compile_count() == before + 1  # telemetry keys anew
    serve_stream("broadcast", stream, telemetry=tel)
    assert engine.compile_count() == before + 1


def test_sweep_telemetry_keys_new_executable():
    from repro.core import sweep as sweep_engine
    from repro.core.sweep import SweepGrid, SweepPoint
    tr = _trace(rounds=64)
    grid = SweepGrid.from_points(
        [SweepPoint("ata", PAPER_GEOMETRY, tr, "ideal", "lax")])
    grid.run()
    before = sweep_engine.compile_count()
    run_off = grid.run()                    # cached: no new compile
    assert sweep_engine.compile_count() == before
    assert run_off.timelines is None
    run_on = grid.run(telemetry=TEL)
    assert sweep_engine.compile_count() == before + 1
    assert len(run_on.timelines) == 1
    run_on.timelines[0].check(run_on.results[0])
    assert run_on.results[0] == run_off.results[0]


# ---------------------------------------------------------------------------
# conservation: window sums == run totals, exactly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("noc", ["ideal", "crossbar"])
@pytest.mark.parametrize("arch", ["private", "ata", "ciao"])
def test_sim_conservation(arch, noc):
    res, tl = simulate(arch, _trace(), noc=noc, telemetry=TEL)
    tl.check(res)                           # raises on any mismatch
    # spot-check the mechanism too: series deltas telescope to totals
    assert tl.series("requests").sum() == tl.total("requests")


@pytest.mark.parametrize("b", [1, 4])
@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_serving_conservation(stream, policy, b):
    res, tl = serve_stream(policy, stream.batched(b), telemetry=TEL)
    tl.check(res)
    assert tl.hist.sum() == res.served.sum()


def test_conservation_error_actually_raises(stream):
    res, tl = serve_stream("ata", stream, telemetry=TEL)
    broken = res._replace(probe_messages=res.probe_messages + 1)
    with pytest.raises(ConservationError):
        tl.check(broken)


# ---------------------------------------------------------------------------
# exact histogram quantiles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q", [0.0, 50.0, 90.0, 99.0, 99.9, 100.0])
@pytest.mark.parametrize("policy", SERVING_POLICIES)
def test_serving_histogram_percentile_is_exact(stream, policy, q):
    """hist_quantile over the value-resolved bincount reproduces
    np.percentile over the materialized latencies bit for bit."""
    res, _ = serve_stream(policy, stream, telemetry=TEL)
    assert res.hist_exact
    lat = res.request_latencies
    assert res.latency_percentile(q) == float(np.percentile(lat, q))


def test_serving_histogram_not_exact_under_fractional_costs(stream):
    """A non-integral cost model falls back to materialized
    percentiles rather than reading a mis-resolved histogram."""
    cfg = ServingConfig(noc="ring")
    res, _ = serve_stream("ata", stream, cfg, telemetry=TEL)
    assert not res.hist_exact
    lat = res.request_latencies
    assert res.latency_percentile(99) == float(np.percentile(lat, 99))


def test_hist_quantile_against_numpy_randomized():
    rng = np.random.default_rng(7)
    values = rng.integers(0, 50, size=500)
    counts = np.bincount(values, minlength=60)
    for q in (0, 1, 25, 50, 75, 90, 99, 99.9, 100):
        assert hist_quantile(counts, q) \
            == float(np.percentile(values, q))


def test_sim_log2_percentile_is_conservative():
    res, tl = simulate("ata", _trace(), telemetry=TEL)
    p99 = tl.hist_percentile(99)
    # bucket upper edge: a power of two and >= the mean latency
    assert p99 == 2.0 ** round(np.log2(p99))
    assert p99 >= res.l1_latency


def test_log2_bucket_edges():
    got = np.asarray(log2_bucket(
        np.asarray([0.0, 1.0, 1.5, 2.0, 3.9, 4.0, 1e12]), 5))
    np.testing.assert_array_equal(got, [0, 0, 0, 1, 1, 2, 4])


def test_serving_hist_bins_covers_max_latency():
    assert serving_hist_bins(720.0) == 722
    assert serving_hist_bins(720.5) == 723


# ---------------------------------------------------------------------------
# exporters: Perfetto traces + run manifests
# ---------------------------------------------------------------------------
def test_sim_trace_validates_and_has_all_track_kinds(tmp_path):
    res, tl = simulate("ata", _trace(), noc="crossbar", telemetry=TEL)
    obj = trace_events(tl)
    validate_trace(obj)
    phs = {e["ph"] for e in obj["traceEvents"]}
    assert phs == {"M", "X", "C"}           # metadata, spans, counters
    path = tmp_path / "sim_trace.json"
    write_trace(str(path), tl)
    validate_trace(json.loads(path.read_text()))


def test_serve_trace_validates(stream, tmp_path):
    _, tl = serve_stream("ata", stream, telemetry=TEL)
    path = tmp_path / "serve_trace.json"
    write_trace(str(path), tl)
    obj = json.loads(path.read_text())
    validate_trace(obj)
    assert any(e["ph"] == "C" for e in obj["traceEvents"])


def test_committed_smoke_trace_is_valid():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baselines", "telemetry_smoke_trace.json")
    validate_trace(json.loads(open(path).read()))


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": []})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [
            {"ph": "X", "name": "n", "pid": 1}]})  # no ts/dur/tid


def test_run_manifest_shape():
    from repro.obs.manifest import run_manifest
    m = run_manifest(phases={"x": 1.25}, extra={"note": "t"})
    assert isinstance(m["git_sha"], str) and len(m["git_sha"]) == 40
    assert m["jax_version"] and m["backend"]
    assert m["phases_wall_s"] == {"x": 1.25}
    assert m["note"] == "t"
    assert "sweep" in m["compile_counts"]
    json.dumps(m)                           # must be JSON-serializable


def test_sensitivity_report_carries_manifest():
    from repro.core import report as sensitivity
    rep = sensitivity.run_sensitivity(
        app="cfd", archs=("ata",), knobs={"hide": (5.0,)},
        kernels_per_app=1, rounds=64)
    assert rep["manifest"]["git_sha"]
    assert "sweep" in rep["manifest"]["phases_wall_s"]


def test_serving_scale_report_carries_manifest_and_exact_quantiles():
    from benchmarks import fig_serving_scale
    rep = fig_serving_scale.run(
        rounds=64, shards=(4,),
        mixes=(ServingMix(("chat", "rag"), name="chat+rag"),),
        policies=("ata",), slot_counts=(1,), reps=1)
    assert rep["manifest"]["git_sha"]
    assert all(c["hist_exact"] for c in rep["cells"])


def test_telemetry_capture_writes_everything(tmp_path):
    from benchmarks import telemetry_capture
    out = tmp_path / "cap"
    rep = telemetry_capture.capture(str(out), rounds=64)
    for name in ("sim_timeline.json", "sim_timeline.csv",
                 "sim_trace.json", "serve_timeline.json",
                 "serve_timeline.csv", "serve_trace.json",
                 "manifest.json", "telemetry_report.json"):
        assert (out / name).exists(), name
    assert rep["kind"] == "telemetry"
    assert rep["serving"]["hist_exact"]
    validate_trace(json.loads((out / "serve_trace.json").read_text()))


# ---------------------------------------------------------------------------
# window invariance: rebin(k) == capture at k*W (exactly)
# ---------------------------------------------------------------------------
def test_rebin_matches_coarser_capture(stream):
    _, fine = serve_stream("ata", stream, telemetry=TelemetryConfig(
        window=16))
    _, coarse = serve_stream("ata", stream, telemetry=TelemetryConfig(
        window=32))
    rebinned = fine.rebin(2)
    assert rebinned.window == coarse.window
    for name in coarse.counter_names:
        np.testing.assert_array_equal(rebinned.cumulative[name],
                                      coarse.cumulative[name], err_msg=name)


def test_window_must_divide_run_length():
    with pytest.raises(ValueError, match="nearest divisor"):
        simulate("ata", _trace(rounds=96),
                 telemetry=TelemetryConfig(window=17))


def test_window_invariance_property(stream):
    """Hypothesis form of the rebin contract: for any divisor pair
    (w1 | w2), a capture at w1 re-binned to w2 equals the capture taken
    at w2 — cumulative snapshots at shared boundaries are identical
    regardless of stride."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    windows = (4, 8, 16, 32)
    captures = {w: serve_stream("ata", stream,
                                telemetry=TelemetryConfig(window=w))[1]
                for w in windows}

    @settings(max_examples=16, deadline=None)
    @given(st.sampled_from(windows), st.sampled_from(windows))
    def prop(w1, w2):
        if w2 % w1:
            return
        rebinned = captures[w1].rebin(w2 // w1)
        coarse = captures[w2]
        assert rebinned.window == coarse.window
        for name in coarse.counter_names:
            np.testing.assert_array_equal(
                rebinned.cumulative[name], coarse.cumulative[name],
                err_msg=f"{name} @ {w1}->{w2}")

    prop()
