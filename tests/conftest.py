import os
import sys

# Tests run single-device (smoke/bench fidelity); multi-device tests
# spawn subprocesses with their own XLA_FLAGS (see helpers below).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
