"""Per-architecture smoke tests: reduced same-family configs, one
forward + one train step on CPU, asserting shapes and no NaNs; decode
consistency for each block family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    out = {"tokens": tokens, "labels": tokens}
    if cfg.is_enc_dec:
        out["enc_frames"] = jax.random.normal(
            RNG, (B, S // 2, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(RNG, cfg)
    b = _batch(cfg)
    logits, aux = T.forward(params, cfg, b["tokens"],
                            enc_frames=b.get("enc_frames"))
    assert logits.shape == (2, 32, T.padded_vocab(cfg))
    assert not np.isnan(np.asarray(logits)).any(), f"{arch}: NaN logits"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    state = init_train_state(RNG, cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10)))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "rwkv6-3b",
                                  "recurrentgemma-9b", "whisper-tiny"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(RNG, cfg)
    B, S = 2, 20
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    kw = ({"enc_frames": jax.random.normal(RNG, (B, S // 2, cfg.d_model),
                                           jnp.float32)}
          if cfg.is_enc_dec else {})
    full, _ = T.forward(params, cfg, tokens, **kw)
    cache = T.init_cache(cfg, B, S, params=params, **kw)
    step = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        lg, cache = step(params, tokens[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.abs(full - dec).max() / (jnp.abs(full).max() + 1e-9))
    assert rel < 2e-2, f"{arch}: decode diverges rel={rel}"


def test_moe_decode_matches_forward_high_capacity():
    cfg = dataclasses.replace(get_smoke_config("granite-moe-1b-a400m"),
                              capacity_factor=8.0)
    params = T.init_params(RNG, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, tokens)
    cache = T.init_cache(cfg, B, S)
    step = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        lg, cache = step(params, tokens[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.abs(full - dec).max() / jnp.abs(full).max())
    assert rel < 2e-2


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (the spec table)."""
    spec = {
        "rwkv6-3b": (32, 2560, 8960, 65536),
        "qwen3-0.6b": (28, 1024, 3072, 151936),
        "qwen1.5-4b": (40, 2560, 6912, 151936),
        "nemotron-4-15b": (32, 6144, 24576, 256000),
        "stablelm-12b": (40, 5120, 13824, 100352),
        "granite-moe-3b-a800m": (32, 1536, 512, 49155),
        "granite-moe-1b-a400m": (24, 1024, 512, 49155),
        "recurrentgemma-9b": (38, 4096, 12288, 256000),
        "whisper-tiny": (4, 384, 1536, 51865),
        "chameleon-34b": (48, 8192, 22016, 65536),
    }
    for arch, (L, d, f, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == \
            (L, d, f, V), arch
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("granite-moe-1b-a400m").n_experts == 32
    assert get_config("recurrentgemma-9b").block_pattern == \
        ("rglru", "rglru", "local_attn")
    assert get_config("qwen1.5-4b").qkv_bias
    assert get_config("qwen3-0.6b").qk_norm
    assert get_config("whisper-tiny").encoder_layers == 4


def test_int8_kv_cache_decode():
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"),
                              kv_cache_dtype="int8")
    params = T.init_params(RNG, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, tokens)
    cache = T.init_cache(cfg, B, S)
    assert cache["layers"]["p0_attn"]["k"].dtype == jnp.int8
    step = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        lg, cache = step(params, tokens[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.abs(full - dec).max() / jnp.abs(full).max())
    assert rel < 5e-2    # quantized: bounded degradation
