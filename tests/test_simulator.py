"""Behavioural + property tests for the ATA-Cache simulator core."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (APPS, PAPER_GEOMETRY, AppParams, make_trace,
                        simulate)
from repro.core.contention import group_rank
from repro.core import tagarray


# ---------------------------------------------------------------------------
# group_rank: the one contention primitive
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=40),
       st.data())
def test_group_rank_matches_python(keys, data):
    mask = data.draw(st.lists(st.booleans(), min_size=len(keys),
                              max_size=len(keys)))
    k = jnp.asarray(keys, jnp.int32)
    m = jnp.asarray(mask)
    rank, size = group_rank(k, m, 8)
    seen = {}
    for i, (key, on) in enumerate(zip(keys, mask)):
        if not on:
            assert int(rank[i]) == 0 and int(size[i]) == 0
            continue
        assert int(rank[i]) == seen.get(key, 0)
        seen[key] = seen.get(key, 0) + 1
    for i, (key, on) in enumerate(zip(keys, mask)):
        if on:
            assert int(size[i]) == seen[key]


# ---------------------------------------------------------------------------
# LRU tag array vs a pure-python reference cache
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=5, max_size=60))
def test_tagarray_lru_matches_reference(addrs):
    n_sets, n_ways = 2, 3
    state = tagarray.init_tag_state(1, n_sets, n_ways)
    ref = {s: [] for s in range(n_sets)}     # list of addrs, MRU last
    for t, a in enumerate(addrs):
        s = a % n_sets
        arr = jnp.asarray([a], jnp.int32)
        si = jnp.asarray([s], jnp.int32)
        zero = jnp.asarray([0], jnp.int32)
        hit, way, _ = tagarray.probe(state, zero, si, arr)
        ref_hit = a in ref[s]
        assert bool(hit[0]) == ref_hit, (t, a)
        if ref_hit:
            state = tagarray.touch(state, zero, si, way,
                                   jnp.int32(t), jnp.asarray([True]))
            ref[s].remove(a)
            ref[s].append(a)
        else:
            state, _ = tagarray.fill(state, zero, si, way, arr,
                                     jnp.int32(t), jnp.asarray([True]))
            if len(ref[s]) == n_ways:
                ref[s].pop(0)                 # evict LRU
            ref[s].append(a)


def test_probe_many_parallel_compare():
    state = tagarray.init_tag_state(4, 2, 2)
    # plant line 7 in caches 1 and 3, set 1
    for c in (1, 3):
        state, _ = tagarray.fill(
            state, jnp.asarray([c]), jnp.asarray([1]), jnp.asarray([0]),
            jnp.asarray([7]), jnp.int32(0), jnp.asarray([True]))
    arrays = jnp.asarray([[0, 1, 2, 3]])
    hits, ways, dirty = tagarray.probe_many(
        state, arrays, jnp.asarray([1]), jnp.asarray([7]))
    assert hits.tolist() == [[False, True, False, True]]


# ---------------------------------------------------------------------------
# architecture-level invariants (reduced workloads for speed)
# ---------------------------------------------------------------------------
def small(app: AppParams) -> AppParams:
    return dataclasses.replace(app, rounds=384)


@pytest.mark.parametrize("app", ["b+tree", "HS3D"])
def test_ata_never_loses_to_private(app):
    tr = make_trace(small(APPS[app]))
    ipc_priv = simulate("private", tr).ipc
    ipc_ata = simulate("ata", tr).ipc
    assert ipc_ata >= ipc_priv * 0.99, (app, ipc_ata, ipc_priv)


def test_ata_hit_rate_exceeds_private_on_shared_workload():
    tr = make_trace(small(APPS["cfd"]))
    r_priv = simulate("private", tr)
    r_ata = simulate("ata", tr)
    assert r_ata.l1_hit_rate > r_priv.l1_hit_rate + 0.1
    assert r_ata.remote_hit_rate > 0.1
    assert r_ata.l2_accesses < r_priv.l2_accesses


def test_ata_zero_probe_traffic_vs_remote_sharing():
    tr = make_trace(small(APPS["cfd"]))
    r_rem = simulate("remote", tr)
    r_ata = simulate("ata", tr)
    # remote-sharing floods the NoC with probes; ATA only moves data
    assert r_ata.noc_flits < 0.5 * r_rem.noc_flits


def test_decoupled_latency_penalty():
    tr = make_trace(small(APPS["doitgen"]))
    lat_priv = simulate("private", tr).l1_latency
    lat_dec = simulate("decoupled", tr).l1_latency
    lat_ata = simulate("ata", tr).l1_latency
    assert lat_dec > lat_priv * 1.2
    assert lat_ata < lat_priv * 1.2


def test_private_and_decoupled_have_no_remote_hits():
    tr = make_trace(small(APPS["b+tree"]))
    assert simulate("private", tr).remote_hit_rate == 0.0
    assert simulate("decoupled", tr).remote_hit_rate == 0.0


def test_trace_determinism():
    t1 = make_trace(APPS["SN"], kernel=2)
    t2 = make_trace(APPS["SN"], kernel=2)
    np.testing.assert_array_equal(t1.addr, t2.addr)
    assert simulate("ata", t1).ipc == simulate("ata", t2).ipc
