"""Behavioural tests for the ATA-Cache simulator core (hypothesis
property tests live in test_properties.py)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (APPS, PAPER_GEOMETRY, AppParams, make_trace,
                        simulate)
from repro.core import tagarray


def test_masked_fill_touch_never_clobber_entry_zero():
    """Regression: masked-out requests used to be parked at (0,0,0) and
    scatter their *old* value back; with duplicate scatter indices a
    late parked lane could revert a genuine update to array 0 / set 0 /
    way 0 (fill undone, dirty bit lost -> missed write-back). They must
    be dropped outright."""
    state = tagarray.init_tag_state(2, 2, 2)
    # request 0: genuine fill at (0,0,0); request 1: masked OUT — its
    # scatter lane must neither land at its own target nor at (0,0,0).
    a = jnp.asarray([0, 1], jnp.int32)
    s = jnp.asarray([0, 1], jnp.int32)
    w = jnp.asarray([0, 1], jnp.int32)
    addr = jnp.asarray([42, 99], jnp.int32)
    mask = jnp.asarray([True, False])
    st, _ = tagarray.fill(state, a, s, w, addr, jnp.int32(3), mask,
                          dirty=jnp.asarray([True, False]))
    assert int(st["tags"][0, 0, 0]) == 42
    assert bool(st["valid"][0, 0, 0]) and bool(st["dirty"][0, 0, 0])
    assert int(st["born"][0, 0, 0]) == 3 and int(st["last"][0, 0, 0]) == 3
    assert not bool(st["valid"][1, 1, 1])          # masked-out: untouched

    # touch: a masked-out lane (and a masked-in read hit) must not
    # clobber the dirty bit a masked-in write sets at (0,0,0).
    st2 = tagarray.touch(st, jnp.asarray([0, 0], jnp.int32),
                         jnp.asarray([0, 0], jnp.int32),
                         jnp.asarray([0, 0], jnp.int32), jnp.int32(7),
                         jnp.asarray([True, True]),
                         set_dirty=jnp.asarray([True, False]))
    assert bool(st2["dirty"][0, 0, 0])
    assert int(st2["last"][0, 0, 0]) == 7

    # all-masked-out ops are exact no-ops on every field
    none = jnp.asarray([False, False])
    st3, wb = tagarray.fill(st, a, s, w, addr, jnp.int32(9), none)
    st4 = tagarray.touch(st, a, s, w, jnp.int32(9), none,
                         set_dirty=jnp.asarray([True, True]))
    for k in st:
        np.testing.assert_array_equal(np.asarray(st3[k]), np.asarray(st[k]))
        np.testing.assert_array_equal(np.asarray(st4[k]), np.asarray(st[k]))
    assert not bool(wb.any())


def test_probe_many_parallel_compare():
    state = tagarray.init_tag_state(4, 2, 2)
    # plant line 7 in caches 1 and 3, set 1
    for c in (1, 3):
        state, _ = tagarray.fill(
            state, jnp.asarray([c]), jnp.asarray([1]), jnp.asarray([0]),
            jnp.asarray([7]), jnp.int32(0), jnp.asarray([True]))
    arrays = jnp.asarray([[0, 1, 2, 3]])
    hits, ways, dirty = tagarray.probe_many(
        state, arrays, jnp.asarray([1]), jnp.asarray([7]))
    assert hits.tolist() == [[False, True, False, True]]


# ---------------------------------------------------------------------------
# architecture-level invariants (reduced workloads for speed)
# ---------------------------------------------------------------------------
def small(app: AppParams) -> AppParams:
    return dataclasses.replace(app, rounds=384)


@pytest.mark.parametrize("app", ["b+tree", "HS3D"])
def test_ata_never_loses_to_private(app):
    tr = make_trace(small(APPS[app]))
    ipc_priv = simulate("private", tr).ipc
    ipc_ata = simulate("ata", tr).ipc
    assert ipc_ata >= ipc_priv * 0.99, (app, ipc_ata, ipc_priv)


def test_ata_hit_rate_exceeds_private_on_shared_workload():
    tr = make_trace(small(APPS["cfd"]))
    r_priv = simulate("private", tr)
    r_ata = simulate("ata", tr)
    assert r_ata.l1_hit_rate > r_priv.l1_hit_rate + 0.1
    assert r_ata.remote_hit_rate > 0.1
    assert r_ata.l2_accesses < r_priv.l2_accesses


def test_ata_zero_probe_traffic_vs_remote_sharing():
    tr = make_trace(small(APPS["cfd"]))
    r_rem = simulate("remote", tr)
    r_ata = simulate("ata", tr)
    # remote-sharing floods the NoC with probes; ATA only moves data
    assert r_ata.noc_flits < 0.5 * r_rem.noc_flits


def test_decoupled_latency_penalty():
    tr = make_trace(small(APPS["doitgen"]))
    lat_priv = simulate("private", tr).l1_latency
    lat_dec = simulate("decoupled", tr).l1_latency
    lat_ata = simulate("ata", tr).l1_latency
    assert lat_dec > lat_priv * 1.2
    assert lat_ata < lat_priv * 1.2


def test_private_and_decoupled_have_no_remote_hits():
    tr = make_trace(small(APPS["b+tree"]))
    assert simulate("private", tr).remote_hit_rate == 0.0
    assert simulate("decoupled", tr).remote_hit_rate == 0.0


def test_trace_determinism():
    t1 = make_trace(APPS["SN"], kernel=2)
    t2 = make_trace(APPS["SN"], kernel=2)
    np.testing.assert_array_equal(t1.addr, t2.addr)
    assert simulate("ata", t1).ipc == simulate("ata", t2).ipc
