"""Hypothesis property tests, collected from across the suite.

They live in their own module so that a missing ``hypothesis`` (the
optional ``test`` extra) degrades to *these* tests skipping while the
example-based tests in test_simulator/test_substrate/test_serving keep
running.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'test' extra "
    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import tagarray
from repro.core.arch.ata import AtaPolicy
from repro.core.arch.ciao import CiaoPolicy
from repro.core.arch.private import PrivatePolicy
from repro.core.arch.victim import VictimPolicy
from repro.core.contention import (_group_rank_onehot, group_prefix_sum,
                                   group_rank)
from repro.core.geometry import GpuGeometry
from repro.core.simulator import _request_batch
from repro.optim.compression import compress, decompress
from repro.serving import hash_blocks


# ---------------------------------------------------------------------------
# group_rank: the one contention primitive
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=40),
       st.data())
def test_group_rank_matches_python(keys, data):
    mask = data.draw(st.lists(st.booleans(), min_size=len(keys),
                              max_size=len(keys)))
    k = jnp.asarray(keys, jnp.int32)
    m = jnp.asarray(mask)
    rank, size = group_rank(k, m, 8)
    seen = {}
    for i, (key, on) in enumerate(zip(keys, mask)):
        if not on:
            assert int(rank[i]) == 0 and int(size[i]) == 0
            continue
        assert int(rank[i]) == seen.get(key, 0)
        seen[key] = seen.get(key, 0) + 1
    for i, (key, on) in enumerate(zip(keys, mask)):
        if on:
            assert int(size[i]) == seen[key]


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(1, 200), st.data())
def test_group_rank_sorted_path_matches_onehot_reference(n_keys, R, data):
    """The hot sort/segment-sum path must return the *identical*
    integers as the O(R*K) one-hot reference — downstream float timing
    (and thus every golden) is bit-exact iff the ranks are."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    keys = jnp.asarray(rng.integers(0, n_keys, R), jnp.int32)
    mask = jnp.asarray(rng.random(R) < data.draw(st.floats(0.0, 1.0)))
    rank_s, size_s = group_rank(keys, mask, n_keys)
    rank_r, size_r = _group_rank_onehot(keys, mask, n_keys)
    assert (np.asarray(rank_s) == np.asarray(rank_r)).all()
    assert (np.asarray(size_s) == np.asarray(size_r)).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(1, 80), st.data())
def test_group_prefix_sum_matches_python(n_keys, R, data):
    """The weighted generalization (NoC port arbitration) against a
    sequential python accumulator."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    keys = rng.integers(0, n_keys, R)
    vals = rng.integers(0, 9, R).astype(np.float32)
    mask = rng.random(R) < 0.7
    before, total = group_prefix_sum(
        jnp.asarray(keys, jnp.int32), jnp.asarray(vals),
        jnp.asarray(mask), n_keys)
    acc = {}
    for i in range(R):
        if mask[i]:
            assert float(before[i]) == acc.get(keys[i], 0.0), i
            acc[keys[i]] = acc.get(keys[i], 0.0) + float(vals[i])
        else:
            assert float(before[i]) == 0.0 and float(total[i]) == 0.0
    for i in range(R):
        if mask[i]:
            assert float(total[i]) == acc[keys[i]]


# ---------------------------------------------------------------------------
# LRU tag array vs a pure-python reference cache
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=5, max_size=60))
def test_tagarray_lru_matches_reference(addrs):
    n_sets, n_ways = 2, 3
    state = tagarray.init_tag_state(1, n_sets, n_ways)
    ref = {s: [] for s in range(n_sets)}     # list of addrs, MRU last
    for t, a in enumerate(addrs):
        s = a % n_sets
        arr = jnp.asarray([a], jnp.int32)
        si = jnp.asarray([s], jnp.int32)
        zero = jnp.asarray([0], jnp.int32)
        hit, way, _ = tagarray.probe(state, zero, si, arr)
        ref_hit = a in ref[s]
        assert bool(hit[0]) == ref_hit, (t, a)
        if ref_hit:
            state = tagarray.touch(state, zero, si, way,
                                   jnp.int32(t), jnp.asarray([True]))
            ref[s].remove(a)
            ref[s].append(a)
        else:
            state, _ = tagarray.fill(state, zero, si, way, arr,
                                     jnp.int32(t), jnp.asarray([True]))
            if len(ref[s]) == n_ways:
                ref[s].pop(0)                 # evict LRU
            ref[s].append(a)


# ---------------------------------------------------------------------------
# scatter-mask invariants: touch/fill mutate masked-in targets only
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_fill_and_touch_scatter_mask_invariants(data):
    n_arrays, n_sets, n_ways = 3, 2, 2
    R = data.draw(st.integers(1, 12))
    idx = st.lists(st.integers(0, 10**6), min_size=R, max_size=R)
    a = np.asarray(data.draw(idx)) % n_arrays
    s = np.asarray(data.draw(idx)) % n_sets
    w = np.asarray(data.draw(idx)) % n_ways
    addr = np.asarray(data.draw(idx), np.int32) + 1
    mask = np.asarray(data.draw(
        st.lists(st.booleans(), min_size=R, max_size=R)))
    dirty = np.asarray(data.draw(
        st.lists(st.booleans(), min_size=R, max_size=R)))

    # a warmed-up state so changes are detectable against non-zeros
    state = tagarray.init_tag_state(n_arrays, n_sets, n_ways)
    warm_a = np.arange(n_arrays).repeat(n_sets * n_ways) % n_arrays
    warm_s = (np.arange(n_arrays * n_sets * n_ways) // n_ways) % n_sets
    warm_w = np.arange(n_arrays * n_sets * n_ways) % n_ways
    state, _ = tagarray.fill(
        state, jnp.asarray(warm_a, jnp.int32), jnp.asarray(warm_s, jnp.int32),
        jnp.asarray(warm_w, jnp.int32),
        jnp.asarray(1000 + np.arange(warm_a.size), jnp.int32),
        jnp.int32(1), jnp.asarray(np.ones(warm_a.size, bool)))

    filled, _ = tagarray.fill(
        state, jnp.asarray(a, jnp.int32), jnp.asarray(s, jnp.int32),
        jnp.asarray(w, jnp.int32), jnp.asarray(addr), jnp.int32(5),
        jnp.asarray(mask), dirty=jnp.asarray(dirty))
    touched = tagarray.touch(
        state, jnp.asarray(a, jnp.int32), jnp.asarray(s, jnp.int32),
        jnp.asarray(w, jnp.int32), jnp.int32(5), jnp.asarray(mask),
        set_dirty=jnp.asarray(dirty))

    targets = {(int(ai), int(si), int(wi))
               for ai, si, wi, m in zip(a, s, w, mask) if m}
    for out in (filled, touched):
        for key in out:
            before, after = np.asarray(state[key]), np.asarray(out[key])
            changed = np.argwhere(before != after)
            for ai, si, wi in changed:
                # every mutation lands on a masked-in target — never on
                # (0,0,0) or anywhere else by accident
                assert (int(ai), int(si), int(wi)) in targets, (
                    key, (ai, si, wi), targets)
    # masked-in fills actually install one of their writers' lines
    tags = np.asarray(filled["tags"])
    for t in targets:
        writers = [int(x) for x, (ai, si, wi, m) in
                   zip(addr, zip(a, s, w, mask)) if m
                   and (int(ai), int(si), int(wi)) == t]
        assert tags[t] in writers
        assert bool(np.asarray(filled["valid"])[t])
    if not mask.any():
        for key in state:
            np.testing.assert_array_equal(np.asarray(filled[key]),
                                          np.asarray(state[key]))


# ---------------------------------------------------------------------------
# policy-zoo degeneracy: zero-sized extensions change nothing, bit-exactly
# ---------------------------------------------------------------------------
#: Small geometry so random traces exercise hits, misses and evictions.
_ZOO_GEOM = GpuGeometry(n_cores=4, cluster_size=2, l1_sets=2, l1_ways=2,
                        l1_banks=2, l2_parts=2, l2_sets=4, l2_ways=2)
_ZOO_M = 2


def _zoo_state_and_reqs(data, *, victim_ways=0, thrash_lanes=0):
    """A randomly warmed L1 state plus one random round's requests."""
    g = _ZOO_GEOM
    state = tagarray.init_tag_state(g.n_cores, g.l1_sets, g.l1_ways,
                                    victim_ways=victim_ways,
                                    thrash_lanes=thrash_lanes)
    R = g.n_cores * _ZOO_M
    lines = st.lists(st.integers(0, 15), min_size=R, max_size=R)
    core = jnp.asarray(np.arange(g.n_cores).repeat(_ZOO_M), jnp.int32)
    for t in range(data.draw(st.integers(1, 3))):    # warm-up fills
        addr = jnp.asarray(data.draw(lines), jnp.int32)
        set_idx = (addr % g.l1_sets).astype(jnp.int32)
        _, way, _ = tagarray.probe(state, core, set_idx, addr)
        state, _ = tagarray.fill(state, core, set_idx, way, addr,
                                 jnp.int32(t), jnp.ones((R,), bool))
    addr = np.asarray(data.draw(lines),
                      np.int32).reshape(g.n_cores, _ZOO_M)
    is_write = np.asarray(data.draw(
        st.lists(st.booleans(), min_size=R, max_size=R))).reshape(
            g.n_cores, _ZOO_M)
    reqs = _request_batch(g, jnp.asarray(addr), jnp.asarray(is_write))
    return state, reqs


def _assert_outcomes_bit_equal(a, b):
    assert set(a.l1.keys()) == set(b.l1.keys())
    for k in a.l1:
        np.testing.assert_array_equal(np.asarray(a.l1[k]),
                                      np.asarray(b.l1[k]), err_msg=k)
    assert (a.bypass_fill is None) == (b.bypass_fill is None)
    for f in a._fields:
        if f in ("l1", "bypass_fill"):
            continue
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_victim_zero_ways_never_changes_ata_behavior(data):
    """A size-0 victim buffer is an exact no-op: the victim policy's
    round is bit-identical to base ATA on any state and request mix."""
    state, reqs = _zoo_state_and_reqs(data, victim_ways=0)
    t = jnp.int32(7)
    base = AtaPolicy().l1_stage(_ZOO_GEOM, state, reqs, t)
    vic = VictimPolicy(victim_ways=0).l1_stage(_ZOO_GEOM, state, reqs, t)
    _assert_outcomes_bit_equal(vic, base)


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_probe_backend_never_changes_the_round(data):
    """The probe backend is a lowering choice, not a model choice: on
    any warmed state and request mix, every CPU-runnable backend's
    ``l1_stage`` is bit-identical — outputs *and* carried tag state —
    so IPC (a pure function of the rounds) cannot depend on it."""
    state, reqs = _zoo_state_and_reqs(data)
    t = jnp.int32(7)
    base = AtaPolicy().l1_stage(_ZOO_GEOM, state, reqs, t,
                                backend="lax")
    for backend in ("lax_unfused", "pallas_interpret"):
        got = AtaPolicy().l1_stage(_ZOO_GEOM, state, reqs, t,
                                   backend=backend)
        _assert_outcomes_bit_equal(got, base)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_ciao_zero_threshold_degenerates_to_private(data):
    """thrash_threshold=0 disables CIAO entirely: outcome and carried
    state (thrash counters included) match the private baseline."""
    state, reqs = _zoo_state_and_reqs(data,
                                      thrash_lanes=_ZOO_GEOM.n_cores)
    t = jnp.int32(7)
    base = PrivatePolicy().l1_stage(_ZOO_GEOM, state, reqs, t)
    ciao = CiaoPolicy(thrash_threshold=0).l1_stage(_ZOO_GEOM, state,
                                                   reqs, t)
    _assert_outcomes_bit_equal(ciao, base)


# ---------------------------------------------------------------------------
# per-app attribution: invariant under app relabeling
# ---------------------------------------------------------------------------
#: Small machine so full simulate() stays cheap inside hypothesis.
_MIX_GEOM = GpuGeometry(n_cores=6, cluster_size=3, l1_sets=2, l1_ways=2,
                        l1_banks=2, l2_parts=2, l2_sets=4, l2_ways=2)


def _tiny_trace(data, core_app):
    from repro.core.simulator import Trace
    T, C, m = 12, _MIX_GEOM.n_cores, 2
    n = T * C * m
    addr = np.asarray(
        data.draw(st.lists(st.integers(0, 63), min_size=n, max_size=n)),
        np.int32).reshape(T, C, m)
    is_write = np.asarray(
        data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    ).reshape(T, C, m)
    return Trace(addr=addr, is_write=is_write, insn_per_req=5.0,
                 core_app=core_app)


@settings(max_examples=10, deadline=None)
@given(st.permutations(range(3)), st.data())
def test_per_app_attribution_invariant_under_relabeling(perm, data):
    """Relabeling which app id each core carries must only relabel the
    per-app attribution block — every AppStats follows its app to the
    new slot with identical counters (cores, requests, hits, cycles,
    latency sums), and the whole-trace SimResult is untouched."""
    from repro.core import simulate
    base_ids = np.asarray([0, 0, 1, 1, 2, 2], np.int32)
    perm = np.asarray(perm, np.int32)
    tr = _tiny_trace(data, base_ids)
    relabeled = tr._replace(core_app=perm[base_ids])
    r0 = simulate("ata", tr, _MIX_GEOM)
    r1 = simulate("ata", relabeled, _MIX_GEOM)
    # the simulation itself must not depend on labels at all
    # (identical-NaN l1_latency counts as equal)
    assert all(x == y or (x != x and y != y)
               for x, y in zip(tuple(r0)[:-1], tuple(r1)[:-1]))
    for a in range(3):
        orig, moved = r0.per_app[a], r1.per_app[int(perm[a])]
        assert moved.cores == orig.cores
        assert moved.requests == orig.requests
        assert moved.cycles == orig.cycles
        assert moved.local_hits == orig.local_hits
        assert moved.remote_hits == orig.remote_hits
        assert moved.l1_lat_n == orig.l1_lat_n
        assert moved.l1_lat_sum == pytest.approx(orig.l1_lat_sum,
                                                 rel=1e-6)
        assert moved.instructions == pytest.approx(orig.instructions,
                                                   rel=1e-12)


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=4,
                max_size=64))
def test_compress_error_feedback_bounded(vals):
    g = jnp.asarray(vals, jnp.float32)
    err = jnp.zeros_like(g)
    q, scale, new_err = compress(g, err)
    rec = decompress(q, scale)
    # EF invariant: rec + new_err == g (+ old err) exactly
    np.testing.assert_allclose(np.asarray(rec + new_err), np.asarray(g),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(new_err).max()) <= float(scale) / 2 + 1e-6


# ---------------------------------------------------------------------------
# serving prefix hash
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 999), min_size=32, max_size=96),
       st.integers(1, 31))
def test_hash_blocks_prefix_property(tokens, cut):
    """Equal prefixes hash equally; diverging blocks diverge after."""
    toks = np.asarray(tokens)
    block = 16
    h1 = hash_blocks(toks, block)
    mod = toks.copy()
    mod[min(cut, len(mod) - 1)] += 1
    h2 = hash_blocks(mod, block)
    cut_block = min(cut, len(mod) - 1) // block
    np.testing.assert_array_equal(h1[:cut_block], h2[:cut_block])
    if len(h1) > cut_block:
        assert (h1[cut_block:] != h2[cut_block:]).all()


# ---------------------------------------------------------------------------
# serving engine: batched admission is slot-sequential by contract
# ---------------------------------------------------------------------------
#: One fixed config so every example reuses the per-(policy, B)
#: executables instead of recompiling (small directory for evictions).
_SERVE_CFG = None


def _serve_cfg():
    global _SERVE_CFG
    if _SERVE_CFG is None:
        from repro.serving.engine import ServingConfig
        _SERVE_CFG = ServingConfig(n_sets=8, n_ways=2)
    return _SERVE_CFG


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(("ata", "private", "broadcast")),
       st.sampled_from((2, 4)),
       st.lists(st.sampled_from(("chat", "rag", "batch")), min_size=1,
                max_size=3, unique=True),
       st.integers(0, 1000))
def test_batched_serve_equals_slot_sequential(policy, B, tenants, seed):
    """The batched round contract, as a property: serving a stream at
    ``B`` slots per shard per round IS serving its slot-sequentialized
    ``B=1`` relabeling — every counter integer-for-integer, every
    per-request array bit-equal — across policies, slot counts, mixes
    and seeds. Only the admission-round critical-path aggregation
    (``cycles``, hence modeled throughput) may differ."""
    from repro.core.trace.serving import ServingMix
    from repro.serving.engine import serve_stream
    stream = ServingMix(tuple(tenants)).make_stream(
        n_shards=4, rounds=24, seed=seed, slots=B)
    cfg = _serve_cfg()
    rb = serve_stream(policy, stream, cfg)
    r1 = serve_stream(policy, stream.slot_sequential(), cfg)
    assert rb.slots == B and r1.slots == 1
    for f in ("n_requests", "local_hits", "remote_hits",
              "recomputed_blocks", "probe_messages",
              "remote_fetch_blocks", "directory_sync_entries"):
        assert getattr(rb, f) == getattr(r1, f), f
    for f in ("shard_load", "latency", "served", "tenant_requests",
              "tenant_hit_blocks", "tenant_blocks",
              "tenant_latency_sum"):
        np.testing.assert_array_equal(
            np.asarray(getattr(rb, f)), np.asarray(getattr(r1, f)),
            err_msg=f)
    assert rb.noc_injected == r1.noc_injected
    # batching can only shorten the modeled critical path
    assert rb.cycles <= r1.cycles


# ---------------------------------------------------------------------------
# serving request streams: mix superposition
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(st.sampled_from(("chat", "rag", "batch")),
       st.integers(2, 6), st.integers(8, 48), st.integers(0, 1000))
def test_one_tenant_mix_equals_solo_stream(tenant, n_shards, rounds,
                                           seed):
    """A ``ServingMix`` of one tenant IS that tenant's solo stream —
    superposition adds nothing when there is nothing to superpose
    (slot 0 applies no hash-space offset, no contention to arbitrate),
    so the engine replays both identically by construction."""
    from repro.core.trace.serving import ServingMix, tenant_stream
    solo = tenant_stream(tenant, n_shards=n_shards, rounds=rounds,
                         seed=seed, slot=0)
    mix = ServingMix((tenant,)).make_stream(n_shards=n_shards,
                                            rounds=rounds, seed=seed)
    assert mix.tenants == (tenant,)
    np.testing.assert_array_equal(mix.valid, solo.valid)
    np.testing.assert_array_equal(mix.hashes, solo.hashes)
    np.testing.assert_array_equal(mix.n_blocks, solo.n_blocks)
    np.testing.assert_array_equal(mix.tenant, solo.tenant)
