"""Contention-policy zoo tests: CIAO throttling + victim tag buffer.

Covers the PR-3 acceptance grid — (private, ata, ciao, victim) x 3
geometries stacks into two dataflow-family executables, bit-identical
to per-point ``simulate`` — plus policy behaviour, the degenerate
configurations (threshold 0 / zero-sized buffer) matching their base
policies through the full simulator, the ``SweepGrid._validate``
stack_key dataflow check, and the sensitivity-report subsystem that
rides the zoo (``repro.core.report``).
"""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (APPS, PAPER_GEOMETRY, SweepGrid, get_arch,
                        make_trace, register_arch, registered_archs,
                        simulate)
from repro.core import report as sensitivity
from repro.core.arch import (AtaPolicy, CiaoPolicy, VictimPolicy,
                             _REGISTRY)


def _trace(app, rounds=768, kernel=0):
    return make_trace(dataclasses.replace(APPS[app], rounds=rounds),
                      kernel=kernel)


def same_result(a, b):
    return all(x == y or (x != x and y != y)
               for x, y in zip(tuple(a), tuple(b)))


@pytest.fixture
def temp_arch():
    """Register policies for one test; always unregister afterwards."""
    names = []

    def _register(policy):
        names.append(policy.name)
        return register_arch(policy, overwrite=True)

    yield _register
    for n in names:
        _REGISTRY.pop(n, None)


# ---------------------------------------------------------------------------
# registration + family membership
# ---------------------------------------------------------------------------
def test_zoo_registered_with_family_stack_keys():
    assert "ciao" in registered_archs()
    assert "victim" in registered_archs()
    assert get_arch("ciao").stack_key == get_arch("private").stack_key
    assert get_arch("victim").stack_key == get_arch("ata").stack_key
    assert get_arch("ciao").track_thrash
    assert get_arch("victim").victim_ways > 0


# ---------------------------------------------------------------------------
# the acceptance grid: 4 archs x 3 geometries, <= 4 executables,
# bit-identical to per-point simulate()
# ---------------------------------------------------------------------------
def test_zoo_grid_stacks_into_two_family_executables():
    traces = [_trace("HS3D", rounds=256, kernel=k) for k in range(2)]
    geoms = [PAPER_GEOMETRY,
             dataclasses.replace(PAPER_GEOMETRY, svc_port=4),
             dataclasses.replace(PAPER_GEOMETRY, lat_l2=240)]
    grid = SweepGrid(("private", "ata", "ciao", "victim"), geoms, traces)
    run = grid.run()
    assert run.report.n_points == 4 * 3 * 2
    assert run.report.n_executables <= 4, run.report
    assert run.report.n_executables == 2, run.report   # 2 families
    for pt, r in zip(grid.points, run.results):
        assert same_result(r, simulate(pt.arch, pt.trace, pt.geom)), \
            (pt.arch, pt.geom.svc_port, pt.geom.lat_l2)


# ---------------------------------------------------------------------------
# policy behaviour on an eviction-heavy (streaming) workload
# ---------------------------------------------------------------------------
def test_ciao_throttles_thrashing_lanes():
    tr = _trace("HS3D")
    base = simulate("private", tr)
    ciao = simulate("ciao", tr)
    # a different policy, not a re-badged private ...
    assert tuple(ciao) != tuple(base)
    # ... that protects the L1 from thrashing fills: hit rate up, fill/
    # write-back NoC traffic down, at (at most) a small deferral cost
    assert ciao.l1_hit_rate > base.l1_hit_rate
    assert ciao.noc_flits < 0.95 * base.noc_flits
    assert ciao.ipc > 0.97 * base.ipc


def test_victim_buffer_recovers_evicted_lines():
    tr = _trace("HS3D")
    base = simulate("ata", tr)
    vic = simulate("victim", tr)
    assert tuple(vic) != tuple(base)
    # recently evicted lines are served from the buffer: hit rate and
    # IPC may only improve (up to noise), L2 pressure drops
    assert vic.l1_hit_rate >= base.l1_hit_rate
    assert vic.ipc >= 0.98 * base.ipc
    assert vic.l2_accesses <= base.l2_accesses


# ---------------------------------------------------------------------------
# degenerate configurations == base policies, through the full simulator
# (the hypothesis variants in test_properties.py check the same at the
# l1_stage level on random states)
# ---------------------------------------------------------------------------
def test_ciao_zero_threshold_degenerates_to_private(temp_arch):
    temp_arch(CiaoPolicy(name="ciao_off", thrash_threshold=0))
    tr = _trace("HS3D", rounds=384)
    assert same_result(simulate("ciao_off", tr), simulate("private", tr))


def test_victim_zero_ways_degenerates_to_ata(temp_arch):
    temp_arch(VictimPolicy(name="victim0", victim_ways=0))
    tr = _trace("HS3D", rounds=384)
    assert same_result(simulate("victim0", tr), simulate("ata", tr))


# ---------------------------------------------------------------------------
# SweepGrid._validate rejects stack_key dataflow mismatches
# ---------------------------------------------------------------------------
def test_sweep_grid_rejects_stack_key_dataflow_mismatch(temp_arch):
    @dataclasses.dataclass(frozen=True)
    class BadStack(AtaPolicy):
        name: str = "test_bad_stack"

        def l1_stage(self, geom, l1, reqs, t, *, backend="lax"):
            out = super().l1_stage(geom, l1, reqs, t, backend=backend)
            # an extra carried state array: a different round dataflow
            return out._replace(l1=dict(out.l1, extra=jnp.zeros(3)))

    temp_arch(BadStack())
    traces = [_trace("cfd", rounds=64)]
    with pytest.raises(ValueError, match="stack_key 'ata'.*test_bad_stack"):
        SweepGrid(("ata", "test_bad_stack"), None, traces)
    # alone (its own one-member family) the policy is not rejected here
    grid = SweepGrid(("test_bad_stack",), None, traces)
    assert len(grid.points) == 1


# ---------------------------------------------------------------------------
# sensitivity reports + the regression gate
# ---------------------------------------------------------------------------
KNOBS = {"hide": (5.0, 10.0)}


def test_sensitivity_report_structure_and_markdown(tmp_path):
    rep = sensitivity.run_sensitivity(
        app="cfd", archs=("private", "ata"), knobs=KNOBS,
        kernels_per_app=1, rounds=64)
    # a solo-only report tags (and gates as) schema 1; only reports
    # carrying the mix section claim SCHEMA_VERSION (= 2)
    assert rep["schema"] == 1
    assert len(rep["cells"]) == 2 * 2            # archs x knob values
    for cell in rep["cells"]:
        for metric in ("ipc", "l1_hit_rate", "remote_hit_rate"):
            assert isinstance(cell[metric], float)
        assert cell["ipc"] > 0
    # cells agree with per-point simulate through the same aggregation
    tr = make_trace(dataclasses.replace(APPS["cfd"], rounds=64))
    base = simulate("ata", tr, PAPER_GEOMETRY)
    cell = next(c for c in rep["cells"]
                if c["arch"] == "ata" and c["value"] == 10.0)
    assert cell["ipc"] == pytest.approx(base.ipc)

    md_path = sensitivity.write_report(str(tmp_path / "rep.json"), rep)
    again = sensitivity.load_report(str(tmp_path / "rep.json"))
    assert again == json.loads(json.dumps(rep))  # JSON-clean roundtrip
    md = open(md_path).read()
    assert "| knob | value | arch |" in md
    assert "| hide | 5 | ata |" in md


def test_compare_reports_flags_drift_and_executable_growth():
    rep = sensitivity.run_sensitivity(
        app="cfd", archs=("private", "ata"), knobs=KNOBS,
        kernels_per_app=1, rounds=64)
    assert sensitivity.compare_reports(rep, rep) == []

    drifted = json.loads(json.dumps(rep))
    drifted["cells"][0]["ipc"] *= 1.2
    fails = sensitivity.compare_reports(rep, drifted)
    assert len(fails) == 1 and "IPC drift" in fails[0]
    # within tolerance passes
    assert sensitivity.compare_reports(rep, drifted, ipc_rtol=0.25) == []

    grown = json.loads(json.dumps(rep))
    grown["sweep"]["n_executables"] += 1
    fails = sensitivity.compare_reports(rep, grown)
    assert len(fails) == 1 and "executable count grew" in fails[0]

    missing = json.loads(json.dumps(rep))
    del missing["cells"][-1]
    assert any("missing" in f
               for f in sensitivity.compare_reports(rep, missing))

    other_cfg = json.loads(json.dumps(rep))
    other_cfg["config"]["rounds"] = 128
    fails = sensitivity.compare_reports(rep, other_cfg)
    assert len(fails) == 1 and "config mismatch" in fails[0]
