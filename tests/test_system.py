"""End-to-end behaviour tests for the full system."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.train import train


def test_training_reduces_loss():
    cfg = get_smoke_config("qwen3-0.6b")
    _, losses = train(cfg, steps=30, global_batch=4, seq_len=64,
                      log_every=100)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_training_moe_reduces_loss():
    cfg = get_smoke_config("granite-moe-1b-a400m")
    _, losses = train(cfg, steps=25, global_batch=4, seq_len=64,
                      log_every=100)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_serve_ata_prefix_reuse_saves_prefill():
    """Two requests sharing a prefix: the second's prefill is shorter."""
    from repro.launch.serve import ModelServer
    from repro.serving import AtaCacheConfig, AtaPrefixCache
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen3-0.6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ata = AtaPrefixCache(AtaCacheConfig(n_shards=2, block_tokens=8),
                         "ata")
    srv = [ModelServer(cfg, params, ata, s, max_len=128) for s in (0, 1)]
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, 32)
    r1 = np.concatenate([prefix, rng.integers(0, cfg.vocab_size, 8)])
    r2 = np.concatenate([prefix, rng.integers(0, cfg.vocab_size, 8)])
    _, m1 = srv[0].serve(r1, decode_steps=2)
    _, m2 = srv[1].serve(r2, decode_steps=2)      # other shard!
    assert m1["reused_blocks"] == 0
    assert m2["reused_blocks"] >= 3               # prefix fetched remotely
    assert m2["prefill_tokens"] < m1["prefill_tokens"]
    assert ata.stats.probe_messages == 0


def test_serve_reuse_preserves_logits():
    """Decode after ATA prefix reuse == decode after full prefill."""
    from repro.launch.serve import ModelServer
    from repro.serving import AtaCacheConfig, AtaPrefixCache
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen3-0.6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prefix = rng.integers(0, cfg.vocab_size, 16)
    req = np.concatenate([prefix, rng.integers(0, cfg.vocab_size, 8)])

    ata = AtaPrefixCache(AtaCacheConfig(n_shards=1, block_tokens=8), "ata")
    srv = ModelServer(cfg, params, ata, 0, max_len=64)
    out_cold, _ = srv.serve(req, decode_steps=4)
    out_warm, m = srv.serve(req, decode_steps=4)   # full prefix reuse
    assert m["reused_blocks"] >= 2
    assert out_cold == out_warm
