"""Substrate tests: checkpoint restart, data determinism, optimizer,
gradient compression, loss machinery."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, make_batch
from repro.optim.adamw import (AdamWConfig, apply_updates, global_norm,
                               init_opt_state, schedule)
from repro.optim.compression import (compress, decompress,
                                     init_error_buffers)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_latest(tmp_path):
    store = CheckpointStore(tmp_path)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    store.save(10, tree, wait=True)
    tree2 = jax.tree.map(jnp.zeros_like, tree)
    restored, step = store.restore(tree2)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_restart_resume_exact(tmp_path):
    """Kill/restart semantics: resumed training is bit-identical."""
    from repro.configs import get_smoke_config
    from repro.launch.train import train
    cfg = get_smoke_config("qwen3-0.6b")
    d1, d2 = tmp_path / "a", tmp_path / "b"
    _, full = train(cfg, steps=8, global_batch=2, seq_len=32,
                    ckpt_dir=str(d1), ckpt_every=4, log_every=100)
    # simulate failure at step 4: train to 4, then resume to 8
    train(cfg, steps=4, global_batch=2, seq_len=32,
          ckpt_dir=str(d2), ckpt_every=4, log_every=100)
    _, resumed = train(cfg, steps=8, global_batch=2, seq_len=32,
                       ckpt_dir=str(d2), ckpt_every=4, log_every=100)
    assert abs(full[-1] - resumed[-1]) < 1e-5, (full[-1], resumed[-1])


def test_checkpoint_elastic_remesh(tmp_path):
    """A checkpoint written unsharded restores onto a different layout
    (device_put with new shardings = elastic re-mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    store = CheckpointStore(tmp_path)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    store.save(1, tree, wait=True)
    from repro.sharding.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = store.restore(tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]


def test_checkpoint_atomic_no_partial(tmp_path):
    store = CheckpointStore(tmp_path)
    tree = {"w": jnp.ones((2,))}
    store.save(1, tree, wait=True)
    # a stale tmp dir from a crashed save must not be visible
    (tmp_path / ".tmp_step_00000002").mkdir()
    assert store.latest_step() == 1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_stateless():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    b1 = make_batch(cfg, 7)
    b2 = make_batch(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_shards_partition_global_batch():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    full = make_batch(cfg, 3)["tokens"]
    parts = [make_batch(DataConfig(vocab_size=100, seq_len=16,
                                   global_batch=8, n_shards=4, shard=s),
                        3)["tokens"] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == b["labels"].shape == (2, 8)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}        # d/dw of w^2
        params, opt, _ = apply_updates(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros((3,))}
    opt = init_opt_state(params)
    huge = {"w": jnp.asarray([1e6, 1e6, 1e6])}
    _, _, metrics = apply_updates(cfg, params, huge, opt)
    assert float(metrics["grad_norm"]) > 1e5   # reported unclipped


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------
def test_error_feedback_accumulates_small_grads():
    """Signals smaller than one quantization step still flow through
    over time thanks to error feedback."""
    g = jnp.full((8,), 0.001)
    g = g.at[0].set(1.0)                   # sets scale ~ 1/127
    err = init_error_buffers({"g": g})["g"]
    total = jnp.zeros_like(g)
    for _ in range(50):
        q, s, err = compress(g, err)
        total = total + decompress(q, s)
    mean_small = float(total[1:].mean()) / 50
    assert abs(mean_small - 0.001) < 2e-4
