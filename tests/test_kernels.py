"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ata_tag_probe import ata_tag_probe, default_interpret
from repro.kernels.flash_attention import flash_attention
from repro.kernels.wkv6 import wkv6

RNG = np.random.default_rng(42)


def randn(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# ata_tag_probe
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("R,C,S,W,br,bc", [
    (128, 8, 8, 64, 64, 4),
    (256, 16, 8, 64, 128, 8),
    (64, 4, 16, 8, 64, 4),
    (32, 2, 2, 4, 32, 2),
])
def test_ata_tag_probe_sweep(R, C, S, W, br, bc):
    tags = jnp.asarray(RNG.integers(0, 4096, (C, S, W)), jnp.int32)
    valid = jnp.asarray(RNG.random((C, S, W)) < 0.7)
    qtag = jnp.asarray(RNG.integers(0, 4096, R), jnp.int32)
    set_idx = jnp.asarray(RNG.integers(0, S, R), jnp.int32)
    h1, w1 = ata_tag_probe(set_idx, qtag, tags, valid, br=br, bc=bc)
    h2, w2 = ref.ata_tag_probe_ref(set_idx, qtag, tags, valid)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(
        np.where(np.asarray(h1), np.asarray(w1), 0),
        np.where(np.asarray(h2), np.asarray(w2), 0))


def test_ata_tag_probe_planted_hits():
    C, S, W, R = 4, 8, 16, 64
    tags = jnp.zeros((C, S, W), jnp.int32)
    valid = jnp.zeros((C, S, W), bool)
    qtag = jnp.asarray(RNG.integers(1, 1000, R), jnp.int32)
    set_idx = jnp.asarray(RNG.integers(0, S, R), jnp.int32)
    tags = tags.at[2, set_idx[5], 3].set(qtag[5])
    valid = valid.at[2, set_idx[5], 3].set(True)
    hits, ways = ata_tag_probe(set_idx, qtag, tags, valid, br=32, bc=2)
    assert bool(hits[5, 2]) and int(ways[5, 2]) == 3
    assert int(hits.sum()) >= 1


def test_ata_tag_probe_interpret_autodetect():
    """interpret=None resolves per platform *outside* the jit boundary:
    on this CPU container it must pick the interpreter (and work)."""
    assert default_interpret() is (jax.default_backend() != "tpu")
    C, S, W, R = 2, 4, 8, 32
    tags = jnp.asarray(RNG.integers(0, 64, (C, S, W)), jnp.int32)
    valid = jnp.asarray(RNG.random((C, S, W)) < 0.7)
    qtag = jnp.asarray(RNG.integers(0, 64, R), jnp.int32)
    set_idx = jnp.asarray(RNG.integers(0, S, R), jnp.int32)
    h_auto, _ = ata_tag_probe(set_idx, qtag, tags, valid)
    h_exp, _ = ata_tag_probe(set_idx, qtag, tags, valid,
                             interpret=default_interpret())
    np.testing.assert_array_equal(np.asarray(h_auto), np.asarray(h_exp))


# ---------------------------------------------------------------------------
# ata_probe_rank (fused probe + winner pick + port arbitration)
# ---------------------------------------------------------------------------
def _rank_inputs(R, C, S, W, G, seed=0, tag_lo=0, tag_hi=48):
    rng = np.random.default_rng(seed)
    tags = jnp.asarray(rng.integers(tag_lo, tag_hi, (C, S, W)), jnp.int32)
    valid = jnp.asarray(rng.random((C, S, W)) < 0.7)
    dirty = jnp.asarray(np.asarray(valid) & (rng.random((C, S, W)) < 0.2))
    qtag = jnp.asarray(rng.integers(tag_lo, tag_hi, R), jnp.int32)
    set_idx = jnp.asarray(rng.integers(0, S, R), jnp.int32)
    core = jnp.asarray(rng.integers(0, C, R), jnp.int32)
    cbase = (core // G) * G
    deny = jnp.asarray(rng.random(R) < 0.2)
    return set_idx, qtag, core, cbase, deny, tags, valid, dirty


def _assert_rank_equal(got, want):
    lh, rok = want[0], want[2]
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(lh))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(rok))
    masks = (None, lh, None, rok, rok, rok)
    for a, b, m in zip(got, want, masks):
        if m is None:
            continue
        np.testing.assert_array_equal(
            np.where(np.asarray(m), np.asarray(a), 0),
            np.where(np.asarray(m), np.asarray(b), 0))


@pytest.mark.parametrize("R,C,S,W,G,br,seed", [
    (128, 8, 8, 64, 4, 64, 0),
    (256, 12, 8, 16, 4, 128, 0),
    (64, 4, 16, 8, 2, 64, 0),
    (60, 6, 4, 8, 3, 16, 1),      # R % br != 0: dead-lane padding
    (150, 30, 8, 64, 10, 128, 0),  # paper geometry at m=5, padded tile
])
def test_ata_probe_rank_sweep(R, C, S, W, G, br, seed):
    args = _rank_inputs(R, C, S, W, G, seed=seed)
    want = ref.ata_probe_rank_ref(*args, cluster_size=G)
    got = ops.ata_probe_rank(*args, cluster_size=G, impl="interpret",
                             br=br)
    assert np.asarray(want[0]).any() and np.asarray(want[2]).any()
    _assert_rank_equal(got, want)


def test_ata_probe_rank_planted_arbitration():
    """Three requests hitting the same peer must queue 0,1,2 in request
    order with group size 3; a denied fourth stays out of the group."""
    C, S, W, G = 4, 4, 4, 4
    R = 8
    tags = jnp.zeros((C, S, W), jnp.int32)
    valid = jnp.zeros((C, S, W), bool)
    dirty = jnp.zeros((C, S, W), bool)
    # line 7 lives only in cache 2, set 1, way 3
    tags = tags.at[2, 1, 3].set(7)
    valid = valid.at[2, 1, 3].set(True)
    set_idx = jnp.full((R,), 1, jnp.int32)
    qtag = jnp.where(jnp.arange(R) < 4, 7, 9).astype(jnp.int32)
    core = jnp.asarray([0, 1, 3, 0, 1, 2, 3, 0], jnp.int32)
    cbase = jnp.zeros((R,), jnp.int32)
    deny = jnp.asarray([False, False, False, True,
                        False, False, False, False])
    out = ops.ata_probe_rank(set_idx, qtag, core, cbase, deny, tags,
                             valid, dirty, cluster_size=G,
                             impl="interpret", br=4)
    local, way, rok, src, rank, size = (np.asarray(x) for x in out)
    assert not local.any()
    assert rok.tolist() == [True, True, True, False,
                            False, False, False, False]
    assert src[:3].tolist() == [2, 2, 2]
    assert rank[:3].tolist() == [0, 1, 2]       # request order
    assert size[:3].tolist() == [3, 3, 3]
    assert size[3] == 0                          # denied: no port slot
    ref_out = ref.ata_probe_rank_ref(set_idx, qtag, core, cbase, deny,
                                     tags, valid, dirty, cluster_size=G)
    _assert_rank_equal(out, ref_out)


def test_ata_probe_rank_counts_carry_across_tiles():
    """br=4 over R=16 with every request targeting one peer: ranks must
    continue across tile boundaries (the carried VMEM counter), not
    restart at 0 per tile."""
    C, S, W, G = 2, 2, 2, 2
    R = 16
    tags = jnp.zeros((C, S, W), jnp.int32).at[1, 0, 1].set(5)
    valid = jnp.zeros((C, S, W), bool).at[1, 0, 1].set(True)
    dirty = jnp.zeros((C, S, W), bool)
    set_idx = jnp.zeros((R,), jnp.int32)
    qtag = jnp.full((R,), 5, jnp.int32)
    core = jnp.zeros((R,), jnp.int32)
    cbase = jnp.zeros((R,), jnp.int32)
    deny = jnp.zeros((R,), bool)
    out = ops.ata_probe_rank(set_idx, qtag, core, cbase, deny, tags,
                             valid, dirty, cluster_size=G,
                             impl="interpret", br=4)
    _, _, rok, _, rank, size = (np.asarray(x) for x in out)
    assert rok.all()
    assert rank.tolist() == list(range(R))
    assert (size == R).all()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,D,bq,bk,causal,window", [
    (1, 4, 4, 128, 128, 64, 64, 64, True, None),
    (2, 8, 2, 256, 256, 64, 128, 128, True, None),     # GQA
    (1, 4, 2, 128, 128, 32, 64, 32, True, 48),         # window
    (2, 4, 4, 64, 64, 128, 64, 64, False, None),       # bidirectional
    (1, 2, 1, 1, 128, 64, 1, 64, False, None),         # decode Tq=1
])
def test_flash_attention_sweep(B, Hq, Hkv, Tq, Tk, D, bq, bk, causal,
                               window):
    q = randn(B, Hq, Tq, D, scale=0.5)
    k = randn(B, Hkv, Tk, D, scale=0.5)
    v = randn(B, Hkv, Tk, D, scale=0.5)
    o1 = flash_attention(q, k, v, causal=causal, window=window,
                         bq=bq, bk=bk)
    o2 = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_kv_len():
    B, Hq, Hkv, Tk, D = 2, 4, 2, 128, 64
    q = randn(B, Hq, 1, D)
    k = randn(B, Hkv, Tk, D)
    v = randn(B, Hkv, Tk, D)
    kl = jnp.asarray([37, 100], jnp.int32)
    o1 = flash_attention(q, k, v, kv_len=kl, causal=False, bq=1, bk=32)
    o2 = ref.attention_len_ref(q, k, v, kl, causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    q = randn(1, 2, 64, 64, dtype=jnp.bfloat16)
    k = randn(1, 2, 64, 64, dtype=jnp.bfloat16)
    v = randn(1, 2, 64, 64, dtype=jnp.bfloat16)
    o1 = flash_attention(q, k, v, causal=True, bq=32, bk=32)
    o2 = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,T,K,V,chunk", [
    (1, 2, 128, 64, 64, 64),
    (2, 3, 192, 64, 64, 32),
    (1, 1, 64, 32, 64, 64),     # K != V
    (2, 2, 256, 64, 64, 128),
])
def test_wkv6_sweep(B, H, T, K, V, chunk):
    r = randn(B, H, T, K, scale=0.5)
    k = randn(B, H, T, K, scale=0.5)
    v = randn(B, H, T, V, scale=0.5)
    w = -jnp.exp(randn(B, H, T, K))
    u = randn(H, K, scale=0.5)
    o1, s1 = wkv6(r, k, v, w, u, chunk=chunk)
    o2, s2 = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-3, atol=2e-4)


def test_wkv6_initial_state_chaining():
    """Processing [first half] then [second half] == whole sequence."""
    B, H, T, K = 1, 2, 128, 64
    r = randn(B, H, T, K, scale=0.5)
    k = randn(B, H, T, K, scale=0.5)
    v = randn(B, H, T, K, scale=0.5)
    w = -jnp.exp(randn(B, H, T, K))
    u = randn(H, K, scale=0.5)
    o_full, s_full = wkv6(r, k, v, w, u, chunk=32)
    h = T // 2
    o1, s1 = wkv6(r[:, :, :h], k[:, :, :h], v[:, :, :h], w[:, :, :h],
                  u, chunk=32)
    o2, s2 = wkv6(r[:, :, h:], k[:, :, h:], v[:, :, h:], w[:, :, h:],
                  u, initial_state=s1, chunk=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 2)),
                               np.asarray(o_full), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-3, atol=2e-4)


def test_wkv6_strong_decay_stable():
    B, H, T, K = 1, 1, 128, 64
    r = randn(B, H, T, K)
    k = randn(B, H, T, K)
    v = randn(B, H, T, K)
    w = jnp.full((B, H, T, K), -20.0)          # near-total decay
    u = randn(H, K)
    o, s = wkv6(r, k, v, w, u, chunk=64)
    assert not bool(jnp.isnan(o).any())
    assert not bool(jnp.isinf(o).any())
