"""Fig. 8: overall IPC of the four architectures, normalized to the
private cache, over the ten-app suite.

All kernels of an app go through ``simulate_batch`` in one compiled
call; ``rounds`` truncates traces for CI smoke runs.
"""
import time

from repro.core import HIGH_LOCALITY, LOW_LOCALITY, geomean, normalized_ipc
from benchmarks.common import cached_suite, emit


def run(kernels_per_app=1, rounds=None):
    t0 = time.perf_counter()
    suite = cached_suite(kernels_per_app=kernels_per_app or None,
                         rounds=rounds)
    ipc = normalized_ipc(suite)
    us = (time.perf_counter() - t0) * 1e6
    for app in list(HIGH_LOCALITY) + list(LOW_LOCALITY):
        emit(f"fig8.{app}.ata_vs_private", us / 40,
             f"{ipc[app]['ata']:.3f}")
        emit(f"fig8.{app}.decoupled_vs_private", us / 40,
             f"{ipc[app]['decoupled']:.3f}")
    hi = geomean([ipc[a]["ata"] for a in HIGH_LOCALITY])
    lo = geomean([ipc[a]["ata"] for a in LOW_LOCALITY])
    emit("fig8.ata_gain_high_locality_pct", us, f"{100*(hi-1):.1f}")
    emit("fig8.ata_gain_low_locality_pct", us, f"{100*(lo-1):.1f}")
    return {"hi": hi, "lo": lo}
