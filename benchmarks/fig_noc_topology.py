"""Interconnect-topology sensitivity: the zoo under real NoC models.

The paper's ATA evaluation assumes an idealized tag-side interconnect;
this figure asks how much of each policy's win survives a *modeled*
one. One ``SweepGrid`` run covers

    archs  x  {ideal, crossbar, ring}  x  noc_bw in (4, 8, 16, 32)

over one high-locality app's kernels — the NoC axis stacks (all
built-in models share one family), so the grid compiles one executable
per architecture family regardless of how many topologies it sweeps.

Emits per (noc, noc_bw): the ata/private IPC ratio — the headline gap
— plus the remote/private ratio (the probe-broadcast baseline is the
topology models' worst case) and ata's mean NoC queue delay. Under
``ideal`` the gap is flat in ``noc_bw`` by construction (private and
ata never consume it); under ``crossbar`` the gap *closes*
monotonically as bandwidth shrinks (ata's remote transfers queue at
the serving ports, private pays nothing), and under ``ring`` likewise
via hop latency + link serialization — the machine-readable twin is
the ``noc`` section of ``repro.core.report.run_sensitivity``.
"""
import dataclasses
import time

from repro.core import PAPER_GEOMETRY, PAPER_NOCS, SweepGrid
from repro.core.metrics import app_traces, grid_app_results, kernel_range
from repro.core.report import NOC_BW_VALUES
from benchmarks.common import emit

APP = "cfd"
ARCHS = ("private", "remote", "ata")
#: Shared with the report's `noc` section — the two surfaces are
#: documented twins and must sweep the same topology grid.
NOCS = PAPER_NOCS
NOC_BW = NOC_BW_VALUES


def run(kernels_per_app=1, rounds=None, archs=ARCHS, nocs=NOCS,
        noc_bw=NOC_BW):
    """Sweep the topology grid; returns {(noc, noc_bw, label): value}."""
    t0 = time.perf_counter()
    archs, nocs, noc_bw = tuple(archs), tuple(nocs), tuple(noc_bw)
    missing = {a for a in ("private", "ata") if a not in archs}
    if missing:
        raise ValueError(
            "fig_noc_topology needs 'private' and 'ata' for the headline "
            f"ata_vs_private ratio; archs={archs} is missing "
            f"{sorted(missing)}")
    traces = app_traces(APP, PAPER_GEOMETRY,
                        kernel_range(APP, kernels_per_app or None),
                        rounds=rounds)
    geoms = [dataclasses.replace(PAPER_GEOMETRY, noc_bw=v)
             for v in noc_bw]
    grid = SweepGrid(archs, geoms, traces, nocs=nocs)
    sweep = grid.run()
    us = (time.perf_counter() - t0) * 1e6
    n_cells = len(archs) * len(geoms) * len(nocs)
    agg = grid_app_results(grid, sweep.results, APP)

    out = {}
    for noc in nocs:
        for v, g in zip(noc_bw, geoms):
            ata = agg[("ata", g, noc)]
            ratio = ata.ipc / agg[("private", g, noc)].ipc
            out[(noc, v, "ata_vs_private")] = ratio
            emit(f"fig_noc.{APP}.{noc}.noc_bw={v:g}.ata_vs_private",
                 us / n_cells, f"{ratio:.3f}")
            if "remote" in archs:
                rratio = (agg[("remote", g, noc)].ipc
                          / agg[("private", g, noc)].ipc)
                out[(noc, v, "remote_vs_private")] = rratio
                emit(f"fig_noc.{APP}.{noc}.noc_bw={v:g}.remote_vs_private",
                     us / n_cells, f"{rratio:.3f}")
            out[(noc, v, "ata_queue_delay")] = ata.noc_mean_queue_delay
            emit(f"fig_noc.{APP}.{noc}.noc_bw={v:g}.ata_queue_delay",
                 us / n_cells, f"{ata.noc_mean_queue_delay:.2f}")
    emit("fig_noc.executables", 0.0, sweep.report.n_executables)
    return out
