"""Fig. 9: per-kernel IPC for SN, conv3d, HS3D, sradv1.

Each (app, arch) sweeps its kernels through one ``simulate_batch`` call.
"""
import time

from benchmarks.common import cached_suite, emit

FIG9_APPS = ("SN", "conv3d", "HS3D", "sradv1")


def run(kernels_per_app=4, rounds=None):
    t0 = time.perf_counter()
    suite = cached_suite(apps=FIG9_APPS,
                         archs=("private", "decoupled", "ata"),
                         kernels_per_app=kernels_per_app or None,
                         rounds=rounds)
    us = (time.perf_counter() - t0) * 1e6
    for app in FIG9_APPS:
        res = suite[app]
        n = len(res["ata"].per_kernel)
        for k in range(n):
            base = res["private"].per_kernel[k].ipc
            emit(f"fig9.{app}.k{k}.ata", us / (3 * n),
                 f"{res['ata'].per_kernel[k].ipc / base:.3f}")
            emit(f"fig9.{app}.k{k}.decoupled", us / (3 * n),
                 f"{res['decoupled'].per_kernel[k].ipc / base:.3f}")
