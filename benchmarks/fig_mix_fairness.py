"""Multi-tenant fairness: the full policy zoo under 2-app co-scheduling.

The paper evaluates one app at a time; this figure asks the question
the zoo was built for — **does ATA's advantage survive (or grow) when
heterogeneous apps fight over one L1 complex?** Four locality mixes
(``repro.core.report.MIX_PAIRINGS``) —

  cfd+b+tree        high x high inter-core locality
  cfd+HS3D          a sharer co-run with a streamer (high x low)
  HS3D+sradv1       both low locality / streaming   (low x low)
  cfd+b+tree+HS3D   a 3-app point: two sharers + a streamer on 10
                    cores each (weighted-speedup ideal = 3)

— each run through all six registered contention policies
(``private, remote, decoupled, ata, ciao, victim``) via
``repro.core.report.mix_grid_run``: one ``SweepGrid`` run covers every
composed mix *and* every per-slot solo baseline, so mixes bucket by
trace kind (no per-mix recompilation) and solo points share the
single-app executables.

Emits per (mix, arch): weighted speedup (ideal = n_apps), unfairness
(max/min slowdown, ideal 1.0), and the mix IPC; plus the headline
ata-vs-private weighted-speedup ratio per pairing. The
machine-readable twin of this sweep is the ``mix`` section of
``repro.core.report.run_sensitivity`` — ``benchmarks.run
--report-json`` computes the grid run once and feeds it to both, so
the mixes are never simulated twice in one invocation.
"""
import time

from repro.core.report import MIX_ARCHS, MIX_PAIRINGS, mix_grid_run
from benchmarks.common import emit

PAIRINGS = MIX_PAIRINGS
ARCHS = MIX_ARCHS


def run(kernels_per_app=1, rounds=None, pairings=None, archs=ARCHS,
        mix_run=None):
    """Sweep the zoo over the pairings; returns {(mix_id, arch): WS}.

    ``kernels_per_app`` is accepted for driver uniformity; mixes always
    co-run each app's canonical calibration kernel (kernel 0).
    ``mix_run`` reuses an existing ``mix_grid_run`` result (it must
    match ``pairings``/``archs``/``rounds``).
    """
    pairings = tuple(PAIRINGS if pairings is None else pairings)
    t0 = time.perf_counter()
    if mix_run is None:
        mix_run = mix_grid_run(pairings, archs, rounds=rounds)
    us = (time.perf_counter() - t0) * 1e6
    n = max(1, len(pairings) * len(archs))

    out = {}
    for mid, per_arch in mix_run.results.items():
        for arch, mr in per_arch.items():
            out[(mid, arch)] = mr.weighted_speedup
            emit(f"fig_mix.{mid}.{arch}.weighted_speedup", us / n,
                 f"{mr.weighted_speedup:.3f}")
            emit(f"fig_mix.{mid}.{arch}.unfairness", us / n,
                 f"{mr.unfairness:.3f}")
            emit(f"fig_mix.{mid}.{arch}.ipc", us / n,
                 f"{mr.shared.ipc:.2f}")
        if "ata" in per_arch and "private" in per_arch:
            ratio = (per_arch["ata"].weighted_speedup
                     / per_arch["private"].weighted_speedup)
            out[(mid, "ata_vs_private")] = ratio
            emit(f"fig_mix.{mid}.ata_vs_private_ws", us / n,
                 f"{ratio:.3f}")
    emit("fig_mix.executables", 0.0, mix_run.report.n_executables)
    return out
