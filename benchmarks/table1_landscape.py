"""Table I landscape: hit rate / L2 demand / NoC contention per design.

Reuses the Fig. 8 sweep's cached AppResults under ``benchmarks.run``.
"""
import time

import numpy as np

from repro.core import HIGH_LOCALITY
from benchmarks.common import cached_suite, emit


def run(kernels_per_app=1, rounds=None):
    t0 = time.perf_counter()
    suite = cached_suite(apps=HIGH_LOCALITY,
                         kernels_per_app=kernels_per_app or None,
                         rounds=rounds)
    us = (time.perf_counter() - t0) * 1e6
    for arch in ("private", "remote", "decoupled", "ata"):
        hr = np.mean([suite[a][arch].l1_hit_rate for a in suite])
        l2 = np.mean([suite[a][arch].l2_accesses for a in suite])
        noc = np.mean([suite[a][arch].per_kernel[0].noc_flits
                       for a in suite])
        emit(f"table1.{arch}.l1_hit_rate", us / 20, f"{hr:.3f}")
        emit(f"table1.{arch}.l2_accesses", us / 20, f"{l2:.0f}")
        emit(f"table1.{arch}.noc_flits", us / 20, f"{noc:.0f}")
