"""Fig. 10: L1 access latency vs private cache.

Reuses the Fig. 8 sweep's cached AppResults when run under
``benchmarks.run`` (same kernels/rounds key), so the figure costs no
extra simulation.
"""
import time

import numpy as np

from benchmarks.common import cached_suite, emit


def run(kernels_per_app=1, rounds=None):
    t0 = time.perf_counter()
    suite = cached_suite(archs=("private", "decoupled", "ata"),
                         kernels_per_app=kernels_per_app or None,
                         rounds=rounds)
    us = (time.perf_counter() - t0) * 1e6
    ratios_d, ratios_a = [], []
    for app, res in suite.items():
        d = res["decoupled"].l1_latency / res["private"].l1_latency
        a = res["ata"].l1_latency / res["private"].l1_latency
        ratios_d.append(d)
        ratios_a.append(a)
        emit(f"fig10.{app}.decoupled_latency_x", us / 30, f"{d:.3f}")
        emit(f"fig10.{app}.ata_latency_x", us / 30, f"{a:.3f}")
    emit("fig10.decoupled_latency_increase_pct", us,
         f"{100*(np.mean(ratios_d)-1):.1f}")
    emit("fig10.ata_latency_increase_pct", us,
         f"{100*(np.mean(ratios_a)-1):.1f}")
