"""Benchmark suite: one module per paper table/figure + kernels +
serving + roofline. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--full] [--rounds N] \
      [--report-json PATH] [--serving-json PATH] [--serving-rounds N] \
      [--telemetry OUT_DIR]

Every figure is timed individually (``figure.<name>.wall_s`` lines)
and run under a failure collector: a figure that raises prints its
traceback, the remaining figures still run, and the process exits
non-zero at the end listing what failed — CI sees every broken figure
in one run instead of one per push.

--telemetry OUT_DIR runs the observability smoke capture
(``benchmarks.telemetry_capture``): one instrumented simulator point
and one instrumented serving replay, writing windowed timelines
(JSON/CSV), Perfetto traces, a run manifest, and a
``kind="telemetry"`` report into OUT_DIR with conservation checked
inline.

--report-json additionally runs the contention-policy-zoo sensitivity
sweep (``repro.core.report``: private/ata/ciao/victim over widened
l1_ways / noc_bw / hide axes) plus the multi-tenant ``mix`` fairness
section (the full zoo over the locality mixes, pairs and a 3-app
point) and the interconnect-topology ``noc`` section (the zoo x
{ideal, crossbar, ring} x noc_bw) and
writes the machine-readable report JSON + markdown table to PATH —
CI's sharded-sweep-smoke job uploads it as an artifact and gates on
drift vs the committed baseline (``benchmarks/baselines/``,
``scripts/check_bench_regression.py``; the gate is schema-versioned,
so a schema-1 baseline still gates the solo cells of a schema-2
report).

--serving-json runs the serving-engine scale grid
(``benchmarks.fig_serving_scale``: shards x traffic mix x serving
policy through the vectorized ``repro.serving.engine``) and writes its
``kind="serving"`` report there; ``--serving-rounds`` fixes the rounds
per stream (CI smoke uses 512 to match
``benchmarks/baselines/serving_rounds512.json``), while the default —
and any ``--full`` run — calibrates rounds so every (shards, mix)
stream replays at least 1,000,000 requests.

--full uses every per-app kernel (Fig. 9 fidelity); default trims for
CI speed on the 1-core container. --rounds truncates every trace (CI
smoke). The figure sweeps run through ``repro.core.sweep.SweepGrid`` —
same-dataflow architectures stacked into shared executables, stacked
grid points sharded across host devices — and share results via
``benchmarks.common.cached_suite``, so fig10/table1 reuse fig8's
simulations. The ``sweep.executables_compiled`` /
``sweep.figures_total_s`` lines surface sweep-engine perf regressions
in CI logs.
"""
import argparse
import sys
import time
import traceback

#: figures that raised this run; non-empty -> exit code 1 at the end
_FAILURES = []


def _figure(name, fn, *args, **kwargs):
    """Run one figure: time it, survive it, account for it.

    A raising figure prints its traceback to stderr and is recorded in
    ``_FAILURES`` (the suite exits non-zero after the *last* figure),
    so CI surfaces every broken figure in a single run. Returns the
    figure's return value, or None on failure.
    """
    from benchmarks.common import emit
    t0 = time.perf_counter()
    try:
        out = fn(*args, **kwargs)
    except Exception:                       # noqa: BLE001
        wall = time.perf_counter() - t0
        print(f"FIGURE FAILED: {name} after {wall:.2f}s",
              file=sys.stderr)
        traceback.print_exc()
        _FAILURES.append(name)
        emit(f"figure.{name}.wall_s", wall * 1e6, "FAILED")
        return None
    wall = time.perf_counter() - t0
    emit(f"figure.{name}.wall_s", wall * 1e6, f"{wall:.2f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=None,
                    help="truncate every trace to N rounds (CI smoke)")
    ap.add_argument("--report-json", default=None, metavar="PATH",
                    help="write the policy-zoo sensitivity report "
                    "(JSON + sibling .md) to PATH")
    ap.add_argument("--serving-json", default=None, metavar="PATH",
                    help="run the serving-engine scale grid and write "
                    "its kind=serving report to PATH")
    ap.add_argument("--serving-rounds", type=int, default=None,
                    help="fixed rounds per serving stream (CI smoke: "
                    "512); default calibrates to >= 1M requests")
    ap.add_argument("--telemetry", default=None, metavar="OUT_DIR",
                    help="run the observability smoke capture and "
                    "write timelines/traces/manifest into OUT_DIR")
    args = ap.parse_args()
    del _FAILURES[:]
    k = 0 if args.full else 1
    k9 = 0 if args.full else 3

    print("name,us_per_call,derived")
    import jax
    from benchmarks import (fig8_ipc, fig9_kernels, fig10_latency,
                            fig_mix_fairness, fig_noc_topology,
                            fig_sweep_geometry, kernel_micro, serving_ata,
                            table1_landscape)
    from benchmarks.common import emit
    from repro.core import sweep as sweep_engine
    t0 = time.perf_counter()
    _figure("fig8_ipc", fig8_ipc.run, kernels_per_app=k,
            rounds=args.rounds)
    _figure("fig9_kernels", fig9_kernels.run, kernels_per_app=k9,
            rounds=args.rounds)
    _figure("fig10_latency", fig10_latency.run, kernels_per_app=k,
            rounds=args.rounds)
    _figure("table1_landscape", table1_landscape.run, kernels_per_app=k,
            rounds=args.rounds)
    _figure("fig_sweep_geometry", fig_sweep_geometry.run,
            kernels_per_app=k, rounds=args.rounds)
    _figure("fig_noc_topology", fig_noc_topology.run, kernels_per_app=k,
            rounds=args.rounds)
    # one fairness grid run serves both the figure and (below) the
    # report's mix section — the mixes are never simulated twice
    from repro.core.report import mix_grid_run
    mix_run = _figure("mix_grid", mix_grid_run, rounds=args.rounds)
    if mix_run is not None:
        _figure("fig_mix_fairness", fig_mix_fairness.run,
                kernels_per_app=k, rounds=args.rounds, mix_run=mix_run)
    wall = time.perf_counter() - t0
    # Sweep-engine perf counters: compile count and wall time make
    # executable-churn regressions visible in CI logs.
    emit("sweep.figures_total_s", wall * 1e6, f"{wall:.2f}")
    emit("sweep.executables_compiled", 0.0, sweep_engine.compile_count())
    emit("sweep.devices", 0.0, len(jax.devices()))
    if args.report_json:
        def _sensitivity():
            from repro.core import report as sensitivity
            t0 = time.perf_counter()
            from repro.core.noc import PAPER_NOCS
            rep = sensitivity.run_sensitivity(
                kernels_per_app=None if args.full else 1,
                rounds=args.rounds,
                mix_pairings=sensitivity.MIX_PAIRINGS, mix_run=mix_run,
                noc_models=PAPER_NOCS)
            md_path = sensitivity.write_report(args.report_json, rep)
            emit("sensitivity.cells", (time.perf_counter() - t0) * 1e6,
                 len(rep["cells"]))
            emit("sensitivity.executables", 0.0,
                 rep["sweep"]["n_executables"])
            emit("sensitivity.mix_cells", 0.0, len(rep["mix"]["cells"]))
            emit("sensitivity.mix_executables", 0.0,
                 rep["mix"]["sweep"]["n_executables"])
            emit("sensitivity.noc_cells", 0.0, len(rep["noc"]["cells"]))
            emit("sensitivity.noc_executables", 0.0,
                 rep["noc"]["sweep"]["n_executables"])
            print(f"sensitivity report: {args.report_json} + {md_path}",
                  file=sys.stderr)
        _figure("sensitivity_report", _sensitivity)

    _figure("kernel_micro", kernel_micro.run)
    _figure("serving_ata", serving_ata.run)

    if args.serving_json:
        def _serving_scale():
            from benchmarks import fig_serving_scale
            t0 = time.perf_counter()
            srep = fig_serving_scale.run(rounds=args.serving_rounds,
                                         out_json=args.serving_json)
            emit("serving.cells", (time.perf_counter() - t0) * 1e6,
                 len(srep["cells"]))
            emit("serving.requests_total", 0.0,
                 sum(c["requests"] for c in srep["cells"]))
            print(f"serving report: {args.serving_json}",
                  file=sys.stderr)
        _figure("serving_scale", _serving_scale)

    if args.telemetry:
        def _telemetry():
            from benchmarks import telemetry_capture
            rep = telemetry_capture.capture(args.telemetry,
                                            rounds=args.rounds)
            emit("telemetry.sim_windows", 0.0,
                 rep["sim"]["n_windows"])
            emit("telemetry.serving_p99", 0.0,
                 f"{rep['serving']['p99_latency']:.1f}cyc")
            print(f"telemetry capture: {args.telemetry}",
                  file=sys.stderr)
        _figure("telemetry_capture", _telemetry)

    # roofline summary (reads dry-run artifacts if present)
    try:
        from benchmarks import roofline
        rows = roofline.table("sp")
        ok = [r for r in rows if r[2] not in ("SKIP", "ERR")]
        for r in ok:
            emit(f"roofline.{r[0]}.{r[1]}.fraction", 0.0, r[7])
        emit("roofline.cells_ok", 0.0, len(ok))
    except Exception as e:                      # noqa: BLE001
        print(f"roofline.skipped,0,{e!r}", file=sys.stderr)

    # probe-kernel roofline: analytic everywhere, measured on TPU
    try:
        from benchmarks import roofline
        for name, _, _, ai, mem_s, comp_s, bound, meas in \
                roofline.kernel_table():
            emit(f"roofline.kernel.{name}", meas if meas is not None
                 else 0.0, f"{bound};ai={ai:.1f};"
                 f"mem={mem_s * 1e6:.2f}us;comp={comp_s * 1e6:.2f}us")
    except Exception as e:                      # noqa: BLE001
        print(f"roofline.kernel.skipped,0,{e!r}", file=sys.stderr)

    if _FAILURES:
        print(f"{len(_FAILURES)} figure(s) failed: "
              f"{', '.join(_FAILURES)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
