"""Benchmark suite: one module per paper table/figure + kernels +
serving + roofline. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--full]

--full uses every per-app kernel (Fig. 9 fidelity); default trims for
CI speed on the 1-core container.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    k = 0 if args.full else 1
    k9 = 0 if args.full else 3

    print("name,us_per_call,derived")
    from benchmarks import (fig8_ipc, fig9_kernels, fig10_latency,
                            kernel_micro, serving_ata, table1_landscape)
    fig8_ipc.run(kernels_per_app=k)
    fig9_kernels.run(kernels_per_app=k9)
    fig10_latency.run(kernels_per_app=k)
    table1_landscape.run(kernels_per_app=k)
    kernel_micro.run()
    serving_ata.run()

    # roofline summary (reads dry-run artifacts if present)
    try:
        from benchmarks import roofline
        rows = roofline.table("sp")
        ok = [r for r in rows if r[2] not in ("SKIP", "ERR")]
        from benchmarks.common import emit
        for r in ok:
            emit(f"roofline.{r[0]}.{r[1]}.fraction", 0.0, r[7])
        emit("roofline.cells_ok", 0.0, len(ok))
    except Exception as e:                      # noqa: BLE001
        print(f"roofline.skipped,0,{e!r}", file=sys.stderr)


if __name__ == "__main__":
    main()
