"""Benchmark suite: one module per paper table/figure + kernels +
serving + roofline. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--full] [--rounds N] \
      [--report-json PATH] [--serving-json PATH] [--serving-rounds N]

--report-json additionally runs the contention-policy-zoo sensitivity
sweep (``repro.core.report``: private/ata/ciao/victim over widened
l1_ways / noc_bw / hide axes) plus the multi-tenant ``mix`` fairness
section (the full zoo over the locality mixes, pairs and a 3-app
point) and the interconnect-topology ``noc`` section (the zoo x
{ideal, crossbar, ring} x noc_bw) and
writes the machine-readable report JSON + markdown table to PATH —
CI's sharded-sweep-smoke job uploads it as an artifact and gates on
drift vs the committed baseline (``benchmarks/baselines/``,
``scripts/check_bench_regression.py``; the gate is schema-versioned,
so a schema-1 baseline still gates the solo cells of a schema-2
report).

--serving-json runs the serving-engine scale grid
(``benchmarks.fig_serving_scale``: shards x traffic mix x serving
policy through the vectorized ``repro.serving.engine``) and writes its
``kind="serving"`` report there; ``--serving-rounds`` fixes the rounds
per stream (CI smoke uses 512 to match
``benchmarks/baselines/serving_rounds512.json``), while the default —
and any ``--full`` run — calibrates rounds so every (shards, mix)
stream replays at least 1,000,000 requests.

--full uses every per-app kernel (Fig. 9 fidelity); default trims for
CI speed on the 1-core container. --rounds truncates every trace (CI
smoke). The figure sweeps run through ``repro.core.sweep.SweepGrid`` —
same-dataflow architectures stacked into shared executables, stacked
grid points sharded across host devices — and share results via
``benchmarks.common.cached_suite``, so fig10/table1 reuse fig8's
simulations. The ``sweep.executables_compiled`` /
``sweep.figures_total_s`` lines surface sweep-engine perf regressions
in CI logs.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=None,
                    help="truncate every trace to N rounds (CI smoke)")
    ap.add_argument("--report-json", default=None, metavar="PATH",
                    help="write the policy-zoo sensitivity report "
                    "(JSON + sibling .md) to PATH")
    ap.add_argument("--serving-json", default=None, metavar="PATH",
                    help="run the serving-engine scale grid and write "
                    "its kind=serving report to PATH")
    ap.add_argument("--serving-rounds", type=int, default=None,
                    help="fixed rounds per serving stream (CI smoke: "
                    "512); default calibrates to >= 1M requests")
    args = ap.parse_args()
    k = 0 if args.full else 1
    k9 = 0 if args.full else 3

    print("name,us_per_call,derived")
    import jax
    from benchmarks import (fig8_ipc, fig9_kernels, fig10_latency,
                            fig_mix_fairness, fig_noc_topology,
                            fig_sweep_geometry, kernel_micro, serving_ata,
                            table1_landscape)
    from benchmarks.common import emit
    from repro.core import sweep as sweep_engine
    t0 = time.perf_counter()
    fig8_ipc.run(kernels_per_app=k, rounds=args.rounds)
    fig9_kernels.run(kernels_per_app=k9, rounds=args.rounds)
    fig10_latency.run(kernels_per_app=k, rounds=args.rounds)
    table1_landscape.run(kernels_per_app=k, rounds=args.rounds)
    fig_sweep_geometry.run(kernels_per_app=k, rounds=args.rounds)
    fig_noc_topology.run(kernels_per_app=k, rounds=args.rounds)
    # one fairness grid run serves both the figure and (below) the
    # report's mix section — the mixes are never simulated twice
    from repro.core.report import mix_grid_run
    mix_run = mix_grid_run(rounds=args.rounds)
    fig_mix_fairness.run(kernels_per_app=k, rounds=args.rounds,
                         mix_run=mix_run)
    wall = time.perf_counter() - t0
    # Sweep-engine perf counters: compile count and wall time make
    # executable-churn regressions visible in CI logs.
    emit("sweep.figures_total_s", wall * 1e6, f"{wall:.2f}")
    emit("sweep.executables_compiled", 0.0, sweep_engine.compile_count())
    emit("sweep.devices", 0.0, len(jax.devices()))
    if args.report_json:
        from repro.core import report as sensitivity
        t0 = time.perf_counter()
        from repro.core.noc import PAPER_NOCS
        rep = sensitivity.run_sensitivity(
            kernels_per_app=None if args.full else 1, rounds=args.rounds,
            mix_pairings=sensitivity.MIX_PAIRINGS, mix_run=mix_run,
            noc_models=PAPER_NOCS)
        md_path = sensitivity.write_report(args.report_json, rep)
        emit("sensitivity.cells", (time.perf_counter() - t0) * 1e6,
             len(rep["cells"]))
        emit("sensitivity.executables", 0.0,
             rep["sweep"]["n_executables"])
        emit("sensitivity.mix_cells", 0.0, len(rep["mix"]["cells"]))
        emit("sensitivity.mix_executables", 0.0,
             rep["mix"]["sweep"]["n_executables"])
        emit("sensitivity.noc_cells", 0.0, len(rep["noc"]["cells"]))
        emit("sensitivity.noc_executables", 0.0,
             rep["noc"]["sweep"]["n_executables"])
        print(f"sensitivity report: {args.report_json} + {md_path}",
              file=sys.stderr)

    kernel_micro.run()
    serving_ata.run()

    if args.serving_json:
        from benchmarks import fig_serving_scale
        t0 = time.perf_counter()
        srep = fig_serving_scale.run(rounds=args.serving_rounds,
                                     out_json=args.serving_json)
        emit("serving.cells", (time.perf_counter() - t0) * 1e6,
             len(srep["cells"]))
        emit("serving.requests_total", 0.0,
             sum(c["requests"] for c in srep["cells"]))
        print(f"serving report: {args.serving_json}", file=sys.stderr)

    # roofline summary (reads dry-run artifacts if present)
    try:
        from benchmarks import roofline
        rows = roofline.table("sp")
        ok = [r for r in rows if r[2] not in ("SKIP", "ERR")]
        for r in ok:
            emit(f"roofline.{r[0]}.{r[1]}.fraction", 0.0, r[7])
        emit("roofline.cells_ok", 0.0, len(ok))
    except Exception as e:                      # noqa: BLE001
        print(f"roofline.skipped,0,{e!r}", file=sys.stderr)

    # probe-kernel roofline: analytic everywhere, measured on TPU
    try:
        from benchmarks import roofline
        for name, _, _, ai, mem_s, comp_s, bound, meas in \
                roofline.kernel_table():
            emit(f"roofline.kernel.{name}", meas if meas is not None
                 else 0.0, f"{bound};ai={ai:.1f};"
                 f"mem={mem_s * 1e6:.2f}us;comp={comp_s * 1e6:.2f}us")
    except Exception as e:                      # noqa: BLE001
        print(f"roofline.kernel.skipped,0,{e!r}", file=sys.stderr)


if __name__ == "__main__":
    main()
