"""Geometry sensitivity: ATA's IPC win vs private across an L1 grid.

Sweeps six geometry knobs around the paper's Table-II point —

  l1_sets      (structural: regroups per shape)
  l1_ways      (structural: associativity)
  svc_port     (ATA remote-data port service time: traced scalar)
  cluster_size (structural: aggregation breadth)
  noc_bw       (probe-network bandwidth: traced scalar)
  hide         (warp-level latency-hiding depth: traced scalar)

— for ``ata`` vs ``private`` over one high-locality app's kernels, all
through one ``SweepGrid`` run per knob via ``cached_grid``. Scalar-only
variants (``svc_port``/``noc_bw``/``hide``) share a single executable;
structural variants compile one per shape. Emits the ata/private IPC
ratio per grid point. The ``noc_bw`` knob additionally sweeps the
``remote`` baseline (its probe network is the only ``noc_bw``
consumer — private/ata are flat on that axis by construction) and
emits the remote/private ratio. The full policy-zoo variant of this
sweep — with ciao/victim and machine-readable output — is
``repro.core.report.run_sensitivity`` (``benchmarks.run
--report-json``).
"""
import dataclasses
import time

from repro.core import PAPER_GEOMETRY
from benchmarks.common import cached_grid, emit

APP = "cfd"
ARCHS = ("private", "ata")

#: knob -> swept values (middle value = the paper geometry's own).
KNOBS = {
    "l1_sets": (4, 8, 16),
    "l1_ways": (32, 64, 128),
    "svc_port": (1, 2, 4),
    "cluster_size": (5, 10, 15),
    "noc_bw": (8.0, 16.0, 32.0),
    "hide": (5.0, 10.0, 20.0),
}


def run(kernels_per_app=1, rounds=None):
    out = {}
    for knob, values in KNOBS.items():
        t0 = time.perf_counter()
        archs = ARCHS + (("remote",) if knob == "noc_bw" else ())
        geoms = [dataclasses.replace(PAPER_GEOMETRY, **{knob: v})
                 for v in values]
        grid = cached_grid([APP], archs, geoms,
                           kernels_per_app=kernels_per_app or None,
                           rounds=rounds)
        us = (time.perf_counter() - t0) * 1e6
        for gi, v in enumerate(values):
            res = grid[gi][APP]
            ratio = res["ata"].ipc / res["private"].ipc
            out[(knob, v)] = ratio
            emit(f"fig_sweep.{APP}.{knob}={v}.ata_vs_private",
                 us / len(values), f"{ratio:.3f}")
            if "remote" in archs:
                rratio = res["remote"].ipc / res["private"].ipc
                out[(knob, v, "remote")] = rratio
                emit(f"fig_sweep.{APP}.{knob}={v}.remote_vs_private",
                     us / len(values), f"{rratio:.3f}")
    return out
