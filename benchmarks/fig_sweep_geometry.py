"""Geometry sensitivity: ATA's IPC win vs private across an L1 grid.

Sweeps three geometry knobs around the paper's Table-II point —

  l1_sets      (structural: regroups per shape)
  svc_port     (ATA remote-data port service time: traced scalar)
  cluster_size (structural: aggregation breadth)

— for ``ata`` vs ``private`` over one high-locality app's kernels, all
through one ``SweepGrid`` run per knob via ``cached_grid``. Scalar-only
variants (``svc_port``) share a single executable; structural variants
compile one per shape. Emits the ata/private IPC ratio per grid point.
"""
import dataclasses
import time

from repro.core import PAPER_GEOMETRY
from benchmarks.common import cached_grid, emit

APP = "cfd"
ARCHS = ("private", "ata")

#: knob -> swept values (middle value = the paper geometry's own).
KNOBS = {
    "l1_sets": (4, 8, 16),
    "svc_port": (1, 2, 4),
    "cluster_size": (5, 10, 15),
}


def run(kernels_per_app=1, rounds=None):
    out = {}
    for knob, values in KNOBS.items():
        t0 = time.perf_counter()
        geoms = [dataclasses.replace(PAPER_GEOMETRY, **{knob: v})
                 for v in values]
        grid = cached_grid([APP], ARCHS, geoms,
                           kernels_per_app=kernels_per_app or None,
                           rounds=rounds)
        us = (time.perf_counter() - t0) * 1e6
        for gi, v in enumerate(values):
            res = grid[gi][APP]
            ratio = res["ata"].ipc / res["private"].ipc
            out[(knob, v)] = ratio
            emit(f"fig_sweep.{APP}.{knob}={v}.ata_vs_private",
                 us / len(values), f"{ratio:.3f}")
    return out
