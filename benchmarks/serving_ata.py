"""ATA serving cache vs baselines (paper SIII adapted to serving)."""
import time

from repro.serving import AtaCacheConfig, POLICIES, run_workload, \
    synth_requests
from benchmarks.common import emit


def run():
    cfg = AtaCacheConfig(n_shards=8)
    reqs = synth_requests(300, n_shards=8, shared_frac=0.75, seed=1)
    for pol in POLICIES:
        t0 = time.perf_counter()
        s = run_workload(pol, cfg, reqs)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"serving.{pol}.hit_rate", us, f"{s.hit_rate:.3f}")
        emit(f"serving.{pol}.probe_messages", us, s.probe_messages)
        emit(f"serving.{pol}.remote_fetch_blocks", us,
             s.remote_fetch_blocks)
        emit(f"serving.{pol}.local_hits", us, s.local_hits)
