"""Small end-to-end telemetry capture: timelines, traces, manifest.

``python -m benchmarks.run --telemetry OUT/`` (or calling
:func:`capture` directly) runs one instrumented simulator point and
one instrumented serving replay, then writes everything the
observability stack can produce into ``OUT/``:

  sim_timeline.json / .csv      windowed counter series (simulator)
  sim_trace.json                Chrome-trace-event (Perfetto) view
  serve_timeline.json / .csv    windowed counter series (serving)
  serve_trace.json              Perfetto view of the replay
  manifest.json                 provenance (git sha, jax, costs, walls)
  telemetry_report.json         ``kind="telemetry"`` summary for
                                bench_history / scripts.bench_trend

Conservation is checked inline (``Timeline.check`` raises
:class:`repro.obs.ConservationError` on any window-sum /= total
mismatch), so a capture that writes files is also a capture that
validated them — CI uploads the directory as a build artifact.
"""
import json
import os

SCHEMA = 1
#: default capture sizes — small enough for the CI smoke lane, large
#: enough that every counter axis (core/app/link/tenant/slot) is hot
SIM_ROUNDS = 96
SERVE_ROUNDS = 256
WINDOW = 32
SIM_ARCH = "ata"
SIM_NOC = "crossbar"          # non-ideal: exercises the link counters
SERVE_POLICY = "ata"
SERVE_SHARDS = 8
SERVE_MIX = ("chat", "rag")


def capture(out_dir, rounds=None, out_json=None):
    """Run the instrumented smoke points and write all artifacts.

    Returns the ``kind="telemetry"`` report dict (also written to
    ``OUT/telemetry_report.json``, and to ``out_json`` when given —
    the nightly job points that at ``bench_history/``).
    """
    from repro.core import PAPER_GEOMETRY, TelemetryConfig, simulate
    from repro.core.metrics import app_traces
    from repro.core.trace.serving import ServingMix
    from repro.obs.manifest import PhaseTimer, run_manifest
    from repro.obs.perfetto import write_trace
    from repro.serving import ServingConfig, serve_stream

    os.makedirs(out_dir, exist_ok=True)
    sim_rounds = rounds if rounds is not None else SIM_ROUNDS
    sim_rounds += -sim_rounds % WINDOW     # window must divide rounds
    telemetry = TelemetryConfig(window=WINDOW)
    timer = PhaseTimer()

    # --- simulator capture -------------------------------------------
    trace = app_traces("cfd", PAPER_GEOMETRY, [0],
                       rounds=sim_rounds)[0]
    with timer.phase("sim"):
        res, stl = simulate(SIM_ARCH, trace, PAPER_GEOMETRY,
                            noc=SIM_NOC, telemetry=telemetry)
    stl.check(res)                         # conservation, or raise
    stl.write_json(os.path.join(out_dir, "sim_timeline.json"))
    stl.write_csv(os.path.join(out_dir, "sim_timeline.csv"))
    write_trace(os.path.join(out_dir, "sim_trace.json"), stl)
    sim_cell = {
        "arch": SIM_ARCH, "noc": SIM_NOC, "app": "cfd",
        "rounds": sim_rounds, "window": WINDOW,
        "n_windows": stl.n_windows,
        "l1_hit_rate": float(res.l1_hit_rate),
        "l1_latency": float(res.l1_latency),
        # log2-bucketed: a conservative upper-edge quantile, tracked
        # for drift (the serving p99 below is the exact one)
        "p99_latency_bucket": stl.hist_percentile(99),
    }

    # --- serving capture ---------------------------------------------
    serve_rounds = rounds if rounds is not None else SERVE_ROUNDS
    mix = ServingMix(SERVE_MIX, name="+".join(SERVE_MIX))
    stream = mix.make_stream(n_shards=SERVE_SHARDS,
                             rounds=serve_rounds, seed=0)
    with timer.phase("serving"):
        sres, vtl = serve_stream(SERVE_POLICY, stream, ServingConfig(),
                                 telemetry=telemetry)
    vtl.check(sres)                        # conservation, or raise
    vtl.write_json(os.path.join(out_dir, "serve_timeline.json"))
    vtl.write_csv(os.path.join(out_dir, "serve_timeline.csv"))
    write_trace(os.path.join(out_dir, "serve_trace.json"), vtl)
    serve_cell = {
        "policy": SERVE_POLICY, "mix": mix.mix_id,
        "shards": SERVE_SHARDS, "rounds": serve_rounds,
        "window": WINDOW, "n_windows": vtl.n_windows,
        "requests": int(sres.n_requests),
        "hit_rate": float(sres.hit_rate),
        "hist_exact": bool(sres.hist_exact),
        "p50_latency": sres.latency_percentile(50),
        "p99_latency": sres.latency_percentile(99),
    }

    manifest = run_manifest(phases=timer.phases)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True, default=str)
        f.write("\n")

    report = {
        "kind": "telemetry",
        "schema": SCHEMA,
        "config": {"window": WINDOW, "rounds": rounds},
        "sim": sim_cell,
        "serving": serve_cell,
        "manifest": manifest,
    }
    for path in filter(None, [os.path.join(out_dir,
                                           "telemetry_report.json"),
                              out_json]):
        with open(path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True, default=str)
            f.write("\n")
    return report


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("out_dir", help="artifact directory (created)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override both capture sizes (CI smoke)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the telemetry report JSON here")
    args = ap.parse_args()
    report = capture(args.out_dir, rounds=args.rounds,
                     out_json=args.json)
    print(f"telemetry capture ok: sim p99<= "
          f"{report['sim']['p99_latency_bucket']:.0f}cyc, serving "
          f"p99={report['serving']['p99_latency']:.1f}cyc "
          f"-> {args.out_dir}")


if __name__ == "__main__":
    main()
