"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference.

On this CPU container the interpret path measures semantics, not TPU
speed; the ref path is the XLA-compiled oracle. us_per_call reported
for both; derived = max |err| vs oracle.
"""
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from benchmarks.common import emit, time_call

RNG = np.random.default_rng(0)


def run():
    # ata_tag_probe
    C, S, W, R = 16, 8, 64, 1024
    tags = jnp.asarray(RNG.integers(0, 4096, (C, S, W)), jnp.int32)
    valid = jnp.asarray(RNG.random((C, S, W)) < 0.7)
    qtag = jnp.asarray(RNG.integers(0, 4096, R), jnp.int32)
    set_idx = jnp.asarray(RNG.integers(0, S, R), jnp.int32)
    us_ref, (h2, w2) = time_call(ops.ata_probe, set_idx, qtag, tags,
                                 valid, impl="ref")
    us_int, (h1, w1) = time_call(ops.ata_probe, set_idx, qtag, tags,
                                 valid, impl="interpret")
    # exact integer kernel: err folds hits and (hit-masked) ways — the
    # way is only defined where the probe hit
    err = max(int(jnp.abs(h1.astype(jnp.int32)
                          - h2.astype(jnp.int32)).max()),
              int(jnp.abs(jnp.where(h2, w1, 0)
                          - jnp.where(h2, w2, 0)).max()))
    emit("kernel.ata_tag_probe.ref", us_ref,
         f"R={R};C={C};hits={int(h2.sum())}")
    emit("kernel.ata_tag_probe.interpret", us_int, f"maxerr={err}")

    # ata_probe_rank (fused probe + winner pick + port arbitration)
    G = 4
    core = jnp.asarray(RNG.integers(0, C, R), jnp.int32)
    cbase = (core // G) * G
    deny = jnp.asarray(RNG.random(R) < 0.2)
    dirty = jnp.asarray(valid & (RNG.random((C, S, W)) < 0.2))
    us_ref, ref_out = time_call(ops.ata_probe_rank, set_idx, qtag, core,
                                cbase, deny, tags, valid, dirty,
                                cluster_size=G, impl="ref")
    us_int, int_out = time_call(ops.ata_probe_rank, set_idx, qtag, core,
                                cbase, deny, tags, valid, dirty,
                                cluster_size=G, impl="interpret")
    lh, rok = ref_out[0], ref_out[2]
    masks = (None, lh, None, rok, rok, rok)   # way/src/rank/size scopes
    err = 0
    for a, b, m in zip(int_out, ref_out, masks):
        d = jnp.abs(a.astype(jnp.int32) - b.astype(jnp.int32))
        err = max(err, int(jnp.where(m, d, 0).max() if m is not None
                           else d.max()))
    emit("kernel.ata_probe_rank.ref", us_ref,
         f"R={R};C={C};G={G};remote={int(rok.sum())}")
    emit("kernel.ata_probe_rank.interpret", us_int, f"maxerr={err}")

    # flash attention
    q = jnp.asarray(RNG.standard_normal((2, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 512, 64)), jnp.float32)
    us_ref, o2 = time_call(ops.attention, q, k, v, impl="ref")
    us_int, o1 = time_call(ops.attention, q, k, v, impl="interpret")
    emit("kernel.flash_attention.ref", us_ref, "B2H8T512D64")
    emit("kernel.flash_attention.interpret", us_int,
         f"maxerr={float(jnp.abs(o1-o2).max()):.2e}")

    # wkv6
    B, H, T, K = 2, 4, 512, 64
    r = jnp.asarray(RNG.standard_normal((B, H, T, K)) * .5, jnp.float32)
    kk = jnp.asarray(RNG.standard_normal((B, H, T, K)) * .5, jnp.float32)
    vv = jnp.asarray(RNG.standard_normal((B, H, T, K)) * .5, jnp.float32)
    w = -jnp.exp(jnp.asarray(RNG.standard_normal((B, H, T, K)), jnp.float32))
    u = jnp.asarray(RNG.standard_normal((H, K)) * .5, jnp.float32)
    us_ref, (o2, _) = time_call(ops.wkv6, r, kk, vv, w, u, impl="ref")
    us_int, (o1, _) = time_call(ops.wkv6, r, kk, vv, w, u,
                                impl="interpret")
    emit("kernel.wkv6.ref_scan", us_ref, "B2H4T512K64")
    emit("kernel.wkv6.interpret_chunked", us_int,
         f"maxerr={float(jnp.abs(o1-o2).max()):.2e}")
