"""Shared benchmark utilities: timing, CSV emission, and a memoized
suite sweep so the figure modules in one ``benchmarks.run`` invocation
share batched simulation results instead of re-running them."""
import time

import jax

_SUITE_CACHE = {}


def cached_suite(apps=None, archs=None, kernels_per_app=None, rounds=None,
                 geom=None):
    """``repro.core.run_suite`` memoized per (app, arch, kernels, rounds,
    geometry).

    Fig. 8 runs the full suite; Fig. 10 and Table I then reuse its
    AppResults for their arch subsets rather than simulating again. Each
    miss sweeps all kernels of the app through ``simulate_batch`` (one
    compiled call per trace shape).
    """
    from repro.core import (APPS, ARCHITECTURES, PAPER_GEOMETRY, run_app)
    from repro.core.metrics import kernel_range
    apps = list(apps or APPS)
    archs = tuple(archs or ARCHITECTURES)
    geom = geom or PAPER_GEOMETRY
    out = {}
    for app in apps:
        out[app] = {}
        for arch in archs:
            key = (app, arch, kernels_per_app, rounds, geom)
            if key not in _SUITE_CACHE:
                _SUITE_CACHE[key] = run_app(
                    app, arch, geom,
                    kernels=kernel_range(app, kernels_per_app),
                    rounds=rounds)
            out[app][arch] = _SUITE_CACHE[key]
    return out


def time_call(fn, *args, reps=3, warmup=1, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6, out   # us


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
