"""Shared benchmark utilities: timing + CSV emission."""
import time

import jax


def time_call(fn, *args, reps=3, warmup=1, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6, out   # us


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
