"""Shared benchmark utilities: timing, CSV emission, and a memoized
suite sweep so the figure modules in one ``benchmarks.run`` invocation
share device-sharded simulation results instead of re-running them."""
import time

import jax

_SUITE_CACHE = {}


def _cache_key(app, arch, kernels_per_app, rounds, geom):
    return (app, arch, kernels_per_app, rounds, geom)


def cached_suite(apps=None, archs=None, kernels_per_app=None, rounds=None,
                 geom=None):
    """``repro.core.run_suite`` memoized per (app, arch, kernels, rounds,
    geometry) cell.

    All cells missing from the cache are swept in *one*
    ``repro.core.sweep.SweepGrid`` run — same-dataflow architectures
    share an executable, same-shape apps share a dispatch, and the
    stacked points shard across the host's devices. Fig. 8 runs the full
    suite; Fig. 10 and Table I then reuse its AppResults for their arch
    subsets rather than simulating again.
    """
    from repro.core import APPS, ARCHITECTURES, PAPER_GEOMETRY
    apps = list(apps or APPS)
    archs = tuple(archs or ARCHITECTURES)
    geom = geom or PAPER_GEOMETRY
    _fill_cache([(app, arch, geom) for app in apps for arch in archs],
                kernels_per_app, rounds)
    return {app: {arch: _SUITE_CACHE[_cache_key(app, arch, kernels_per_app,
                                                rounds, geom)]
                  for arch in archs}
            for app in apps}


def _fill_cache(cells, kernels_per_app, rounds):
    """Sweep every (app, arch, geom) cell missing from the cache in one
    ``repro.core.metrics.sweep_cells`` grid run."""
    from repro.core.metrics import (AppResult, app_traces, kernel_range,
                                    sweep_cells)
    missing = [c for c in dict.fromkeys(cells)
               if _cache_key(c[0], c[1], kernels_per_app, rounds, c[2])
               not in _SUITE_CACHE]
    traces = {}
    for app, _, geom in missing:
        # traces depend on the geometry only through n_cores
        if (app, geom.n_cores) not in traces:
            traces[(app, geom.n_cores)] = app_traces(
                app, geom, kernel_range(app, kernels_per_app),
                rounds=rounds)
    results = sweep_cells(
        ((app, arch, geom), arch, geom, traces[(app, geom.n_cores)])
        for app, arch, geom in missing)
    for (app, arch, geom), per_kernel in results.items():
        _SUITE_CACHE[_cache_key(app, arch, kernels_per_app, rounds,
                                geom)] = AppResult(app, arch, per_kernel)


def cached_grid(apps, archs, geoms, kernels_per_app=None, rounds=None):
    """Geometry-axis variant of :func:`cached_suite`.

    Returns ``{geom_index: {app: {arch: AppResult}}}`` over the full
    (app x arch x geom) grid, sweeping every missing cell in one
    ``SweepGrid`` run (geometries differing only in timing scalars share
    executables; structural variants group per shape).
    """
    geoms = list(geoms)
    apps = list(apps)
    archs = tuple(archs)
    _fill_cache([(app, arch, geom) for geom in geoms for app in apps
                 for arch in archs], kernels_per_app, rounds)
    return {gi: {app: {arch: _SUITE_CACHE[_cache_key(
                app, arch, kernels_per_app, rounds, geom)]
                       for arch in archs}
                 for app in apps}
            for gi, geom in enumerate(geoms)}


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def time_call(fn, *args, reps=3, warmup=1, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6, out   # us
