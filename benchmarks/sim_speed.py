"""Simulator throughput per probe backend: rounds/sec over the
geometry-sweep grid.

The fused probe+rank+arbitrate path (``repro.core.probe``, backend
``lax``) exists to make the simulator *faster* without changing a
single output bit; this benchmark is the measurement that claim rides
on. It times a full :class:`repro.core.sweep.SweepGrid` run — the
unique geometries of ``fig_sweep_geometry``'s six knobs (13 shapes,
structural recompiles included in warmup, excluded from timing) x the
``ata`` policy x one ``cfd`` kernel — once per probe backend, and
reports rounds simulated per wall-clock second (best of ``reps``
timed runs after a warmup run).

``lax`` vs ``lax_unfused`` is the headline: the same sweep with and
without the fused restructuring, so ``fused_speedup`` isolates the
optimization on identical hardware. ``pallas_interpret`` (off by
default, ``--interpret``) is a correctness artifact, not a speed
path — the interpreter is orders of magnitude slower and is timed at
one small point only.

The report (``--json``) is schema-versioned and gated in CI against
``benchmarks/baselines/simspeed_rounds64.json`` by
``scripts/check_bench_regression.py`` (which dispatches on
``kind == "simspeed"`` to ``repro.core.report.compare_simspeed``):
the *ratio* is gated — absolute rounds/sec varies with the host, the
fused-vs-unfused speedup on one host does not. The nightly job
appends the report to ``bench_history/`` so ``scripts/bench_trend.py``
tracks absolute throughput drift across (comparable) runners too.
"""
import argparse
import dataclasses
import json
import time

from repro.core import PAPER_GEOMETRY
from repro.core.metrics import app_traces
from repro.core.sweep import SweepGrid, SweepPoint
from repro.obs.manifest import run_manifest
from benchmarks.common import emit

APP = "cfd"
KERNEL = 0
ARCH = "ata"
SCHEMA = 1
#: headline = fused lax vs the historical unfused chain
DEFAULT_BACKENDS = ("lax", "lax_unfused")


def unique_geometries():
    """The deduplicated geometry set of the six fig_sweep knobs."""
    from benchmarks.fig_sweep_geometry import KNOBS
    geoms = []
    for knob, values in KNOBS.items():
        for v in values:
            g = dataclasses.replace(PAPER_GEOMETRY, **{knob: v})
            if g not in geoms:
                geoms.append(g)
    return geoms


def _grid(geoms, traces, backend):
    return SweepGrid.from_points(
        [SweepPoint(ARCH, g, traces[g.n_cores], "ideal", backend)
         for g in geoms])


def _time_backend(geoms, traces, backend, rounds, reps):
    grid = _grid(geoms, traces, backend)
    warm = grid.run()                       # compiles included here
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        grid.run()
        best = min(best, time.perf_counter() - t0)
    sim_rounds = len(geoms) * rounds
    return {
        "backend": backend,
        "n_points": len(geoms),
        "rounds": rounds,
        "wall_s": best,
        "rounds_per_sec": sim_rounds / best,
        "n_executables": warm.report.n_executables,
    }


def run(rounds=64, reps=3, backends=DEFAULT_BACKENDS, interpret=False,
        out_json=None, geoms=None):
    geoms = list(geoms) if geoms is not None else unique_geometries()
    traces = {}
    for g in geoms:
        if g.n_cores not in traces:
            traces[g.n_cores] = app_traces(APP, g, [KERNEL],
                                           rounds=rounds)[0]
    cells = []
    for backend in backends:
        cell = _time_backend(geoms, traces, backend, rounds, reps)
        cells.append(cell)
        emit(f"sim_speed.{backend}", cell["wall_s"] * 1e6,
             f"{cell['rounds_per_sec']:.0f} rounds/s")
    if interpret:
        # one small point: the interpreter validates semantics, its
        # wall time is not a useful speed signal beyond "still runs"
        cell = _time_backend(geoms[:1], traces, "pallas_interpret",
                             rounds, 1)
        cells.append(cell)
        emit("sim_speed.pallas_interpret", cell["wall_s"] * 1e6,
             f"{cell['rounds_per_sec']:.0f} rounds/s")

    rps = {c["backend"]: c["rounds_per_sec"] for c in cells}
    headline = {}
    if "lax" in rps and "lax_unfused" in rps:
        headline["fused_speedup"] = rps["lax"] / rps["lax_unfused"]
        emit("sim_speed.fused_speedup", 0.0,
             f"{headline['fused_speedup']:.3f}x")
    report = {
        "kind": "simspeed",
        "schema": SCHEMA,
        "config": {"app": APP, "kernel": KERNEL, "arch": ARCH,
                   "rounds": rounds, "n_geoms": len(geoms)},
        "sweep": {"n_executables": sum(c["n_executables"]
                                       for c in cells)},
        "cells": cells,
        "headline": headline,
        # provenance; compare_simspeed iterates only the baseline's
        # sections, so the block never breaks committed baselines
        "manifest": run_manifest(
            phases={f"backend.{c['backend']}": c["wall_s"]
                    for c in cells}),
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=64,
                    help="trace rounds per point (default 64)")
    ap.add_argument("--reps", type=int, default=3,
                    help="timed repetitions, best taken (default 3)")
    ap.add_argument("--interpret", action="store_true",
                    help="also time pallas_interpret at one point")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the simspeed report JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(rounds=args.rounds, reps=args.reps, interpret=args.interpret,
        out_json=args.json)


if __name__ == "__main__":
    main()
