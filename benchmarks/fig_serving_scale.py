"""Serving-engine scale benchmark: shards x traffic mix x policy x B.

Replays multi-tenant :class:`~repro.core.trace.serving.ServingMix`
request streams through the vectorized serving engine
(``repro.serving.engine``) at production request counts — the default
full run targets >= 1,000,000 requests per (shards, mix) stream, all
in ``lax.scan`` steps with no per-request Python — and reports per
cell: hit rate, probe/fetch/recompute counters, replay throughput
(requests per wall-second, warmed best-of-``reps`` — the engine
materializes its outputs as numpy, so the timed span is device-synced
by construction), and modeled p50/p99 request latency.

The grid is the paper's story at serving scale: ``broadcast`` pays a
probe message per locally-missing block per peer, ``ata``'s replicated
block directory pays zero and still fetches remote blocks it *knows*
exist, ``private`` recomputes everything it lacks. More shards widen
the gap (more peers to probe, more remote reuse to find).

Each cell runs at every ``SLOT_COUNTS`` batch width over the *same*
request population (``stream.batched(B)`` relabels rounds, it never
changes counters — slot-order exactness is tier-1 tested), so the
per-B cells isolate the throughput model: at ``B`` admissions per
round the engine charges one round of critical-path latency per ``B``
requests, and the ``batched_model_speedup`` headline (the ratio of
modeled requests-per-kcycle, B=max vs B=1) is the machine-portable
number CI gates at >= 1.5x. Wall-clock replay speed is reported per B
too (``batched_wall_speedup``) but only loosely gated: the batched
contract replays slots as sequential sub-rounds to stay bit-exact, so
host wall time tracks admitted blocks, not rounds (ARCHITECTURE.md,
"Serving engine" — batched round contract).

``--json`` writes a ``kind="serving"`` report gated in CI against
``benchmarks/baselines/serving_rounds512.json`` by
``scripts/check_bench_regression.py`` (dispatching to
``repro.core.report.compare_serving``): hit rate, probe-message
counts, and the batched-speedup headline are the blocking metrics —
the stream is seeded and the engine integer-deterministic, so probe
counts gate *exactly*; wall-clock throughput is informational
(host-dependent) but tracked by the nightly ``scripts/bench_trend.py``
history.
"""
import argparse
import json
import math
import time

from benchmarks.common import emit

SCHEMA = 2
SHARD_COUNTS = (8, 16)
#: >= 2 traffic mixes: a high-sharing diurnal pair and a bursty
#: low-sharing pair (tenant table: repro.core.trace.serving.TENANTS).
MIX_NAMES = (("chat", "rag"), ("chat", "batch"))
#: Admission widths benchmarked per cell; the batched-speedup headline
#: compares the widest against B=1.
SLOT_COUNTS = (1, 4)
#: Rounds used when --rounds is not given: calibrated per (shards,
#: mix) so every stream carries at least --requests requests.
DEFAULT_REQUESTS = 1_000_000
_CALIB_ROUNDS = 2048


def _mixes():
    from repro.core.trace.serving import ServingMix
    return tuple(ServingMix(names, name="+".join(names))
                 for names in MIX_NAMES)


def _rounds_for(mix, n_shards, target, seed):
    """Rounds so the mix's stream offers >= target admitted requests."""
    probe = mix.make_stream(n_shards=n_shards, rounds=_CALIB_ROUNDS,
                            seed=seed)
    occupancy = max(probe.n_requests / (_CALIB_ROUNDS * n_shards), 1e-3)
    return math.ceil(1.02 * target / (occupancy * n_shards))


def _timed_serve(policy, stream, cfg, reps, telemetry=None):
    """Warmed best-of-``reps`` replay (the sim_speed timing idiom).

    The timed span always replays the plain (``telemetry=None``)
    executable so wall-clock numbers stay comparable across runs; when
    ``telemetry`` is given, one extra instrumented replay supplies the
    result whose latency histogram makes ``p50/p99`` exact quantile
    reads (counters are bit-identical either way — tier-1 tested).
    """
    from repro.serving import serve_stream
    res = serve_stream(policy, stream, cfg)   # warmup (compiles too)
    timeline = None
    if telemetry is not None:
        res, timeline = serve_stream(policy, stream, cfg,
                                     telemetry=telemetry)
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        serve_stream(policy, stream, cfg)
        best = min(best, time.perf_counter() - t0)
    return res, timeline, best


def run(rounds=None, n_requests=DEFAULT_REQUESTS, shards=SHARD_COUNTS,
        mixes=None, policies=None, slot_counts=SLOT_COUNTS, reps=2,
        cfg=None, seed=0, out_json=None, telemetry=None):
    from repro.core.telemetry import TelemetryConfig
    from repro.obs.manifest import PhaseTimer, run_manifest
    from repro.serving import SERVING_POLICIES, ServingConfig
    cfg = cfg or ServingConfig()
    if telemetry is None:
        # default on: the reported p50/p99 become exact histogram
        # quantiles instead of percentiles over materialized latencies
        telemetry = TelemetryConfig()
    timer = PhaseTimer()
    mixes = _mixes() if mixes is None else mixes
    policies = tuple(policies or SERVING_POLICIES)
    slot_counts = tuple(sorted(set(slot_counts)))
    b_max = max(slot_counts)
    cells = []
    probe_msgs = {}
    hit_rates = {}
    model_ratios = []
    wall_ratios = []
    for s in shards:
        for mix in mixes:
            r = rounds if rounds is not None else _rounds_for(
                mix, s, n_requests, seed)
            r += -r % b_max   # every B must divide the row count
            stream = mix.make_stream(n_shards=s, rounds=r, seed=seed)
            if rounds is None:
                assert stream.n_requests >= n_requests, \
                    (stream.n_requests, n_requests)
            for policy in policies:
                by_b = {}
                for b in slot_counts:
                    with timer.phase(f"replay.{policy}"):
                        res, _tl, wall = _timed_serve(
                            policy, stream.batched(b), cfg, reps,
                            telemetry=telemetry)
                    rps = stream.n_requests / wall
                    by_b[b] = (res, rps)
                    cells.append({
                        "shards": s, "mix": mix.mix_id,
                        "policy": policy, "slots": b,
                        "rounds": r, "requests": stream.n_requests,
                        "hit_rate": res.hit_rate,
                        "local_hits": res.local_hits,
                        "remote_hits": res.remote_hits,
                        "recomputed_blocks": res.recomputed_blocks,
                        "probe_messages": res.probe_messages,
                        "remote_fetch_blocks": res.remote_fetch_blocks,
                        "p50_latency": res.p50_latency,
                        "p99_latency": res.p99_latency,
                        "hist_exact": res.hist_exact,
                        "throughput_rps": rps,
                        "requests_per_kcycle": res.requests_per_kcycle,
                        "load_imbalance": res.load_imbalance,
                        "wall_s": wall,
                    })
                    if b == 1:
                        probe_msgs.setdefault(policy, 0)
                        probe_msgs[policy] += res.probe_messages
                        hit_rates.setdefault(policy, []) \
                            .append(res.hit_rate)
                    emit(f"serving_scale.s{s}.{mix.mix_id}.{policy}"
                         f".b{b}.hit_rate",
                         wall * 1e6, f"{res.hit_rate:.4f}")
                    emit(f"serving_scale.s{s}.{mix.mix_id}.{policy}"
                         f".b{b}.p99",
                         wall * 1e6, f"{res.p99_latency:.1f}cyc "
                         f"{rps:.0f}req/s")
                if b_max > 1 and 1 in by_b and b_max in by_b:
                    r1, rps1 = by_b[1]
                    rb, rpsb = by_b[b_max]
                    model_ratios.append(rb.requests_per_kcycle
                                        / max(r1.requests_per_kcycle,
                                              1e-9))
                    wall_ratios.append(rpsb / max(rps1, 1e-9))

    headline = {}
    if "broadcast" in probe_msgs and "ata" in probe_msgs:
        # the paper's claim at serving scale: the replicated directory
        # filters every probe message the broadcast baseline sends
        headline["probes_filtered"] = probe_msgs["broadcast"] \
            - probe_msgs["ata"]
    if "ata" in hit_rates and "private" in hit_rates:
        n = len(hit_rates["ata"])
        headline["ata_vs_private_hit_gain"] = (
            sum(hit_rates["ata"]) - sum(hit_rates["private"])) / n
        emit("serving_scale.ata_vs_private_hit_gain", 0.0,
             f"{headline['ata_vs_private_hit_gain']:+.4f}")
    if model_ratios:
        # modeled req/cycle throughput, B=max vs B=1, worst cell (the
        # one-sided CI floor gates this at >= 1.5x); wall ratio rides
        # along informationally (see the module docstring)
        headline["batched_slots"] = b_max
        headline["batched_model_speedup"] = min(model_ratios)
        headline["batched_wall_speedup"] = min(wall_ratios)
        emit("serving_scale.batched_model_speedup", 0.0,
             f"{headline['batched_model_speedup']:.2f}x@B={b_max}")
        emit("serving_scale.batched_wall_speedup", 0.0,
             f"{headline['batched_wall_speedup']:.2f}x@B={b_max}")

    report = {
        "kind": "serving",
        "schema": SCHEMA,
        "config": {
            "shards": list(shards),
            "mixes": [m.mix_id for m in mixes],
            "policies": list(policies),
            "slot_counts": list(slot_counts),
            "rounds": rounds,
            "n_requests": None if rounds is not None else n_requests,
            "seed": seed,
            "n_sets": cfg.n_sets, "n_ways": cfg.n_ways,
            "noc": cfg.noc, "probe_backend": cfg.probe_backend,
        },
        "cells": cells,
        "headline": headline,
        "manifest": run_manifest(phases=timer.phases),
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=None,
                    help="fixed rounds per stream (CI smoke); default "
                    "calibrates rounds to reach --requests")
    ap.add_argument("--requests", type=int, default=DEFAULT_REQUESTS,
                    help="minimum requests per (shards, mix) stream "
                    "(default 1,000,000)")
    ap.add_argument("--shards", type=int, nargs="+",
                    default=list(SHARD_COUNTS))
    ap.add_argument("--slots", type=int, nargs="+",
                    default=list(SLOT_COUNTS),
                    help="admission widths per cell (default 1 4)")
    ap.add_argument("--reps", type=int, default=2,
                    help="timed repetitions after warmup, best taken "
                    "(default 2)")
    ap.add_argument("--noc", default="ideal",
                    help="interconnect model pricing remote fetches")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the kind=serving report JSON here")
    args = ap.parse_args()
    from repro.serving import ServingConfig
    print("name,us_per_call,derived")
    run(rounds=args.rounds, n_requests=args.requests,
        shards=tuple(args.shards), slot_counts=tuple(args.slots),
        reps=args.reps, cfg=ServingConfig(noc=args.noc),
        out_json=args.json)


if __name__ == "__main__":
    main()
