"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio), and the
roofline fraction = (MODEL_FLOPS/chips/peak) / max(term) — the score a
perfect-efficiency implementation would push to 1.0.
"""
import glob
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"
PEAK_FLOPS = 197e12


def load(mesh="sp"):
    rows = []
    for f in sorted(glob.glob(str(RESULTS / f"*__{mesh}.json"))):
        r = json.load(open(f))
        rows.append(r)
    return rows


def fraction(r):
    if r["status"] != "ok":
        return None
    t = r["roofline"]
    ideal = t["model_flops_global"] / r["chips"] / PEAK_FLOPS
    bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return ideal / bound if bound else None


def table(mesh="sp"):
    rows = []
    for r in load(mesh):
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], "SKIP", r.get("reason", "")))
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], "ERR", r.get("error", "")[:60]))
            continue
        t = r["roofline"]
        frac = fraction(r)
        rows.append((
            r["arch"], r["shape"], t["dominant"],
            f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
            f"{t['collective_s']:.4f}",
            f"{t['useful_flops_ratio']:.2f}" if t["useful_flops_ratio"] else "-",
            f"{frac:.3f}" if frac else "-",
            f"{r['memory']['peak_estimate_gb']:.1f}GB",
        ))
    return rows


def main():
    for mesh, name in (("sp", "single-pod 16x16"), ("mp", "multi-pod 2x16x16")):
        print(f"\n=== roofline: {name} ===")
        print(f"{'arch':22s} {'shape':12s} {'bound':10s} {'comp_s':>8s} "
              f"{'mem_s':>8s} {'coll_s':>8s} {'useful':>6s} {'frac':>6s} {'peak':>8s}")
        for row in table(mesh):
            if row[2] in ("SKIP", "ERR"):
                print(f"{row[0]:22s} {row[1]:12s} {row[2]:10s} {row[3][:50]}")
            else:
                print(f"{row[0]:22s} {row[1]:12s} {row[2]:10s} "
                      f"{row[3]:>8s} {row[4]:>8s} {row[5]:>8s} {row[6]:>6s} "
                      f"{row[7]:>6s} {row[8]:>8s}")


if __name__ == "__main__":
    main()
