"""Roofline table from the dry-run artifacts (results/dryrun/*.json),
plus an analytic roofline for the Pallas probe kernels.

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio), and the
roofline fraction = (MODEL_FLOPS/chips/peak) / max(term) — the score a
perfect-efficiency implementation would push to 1.0.

:func:`kernel_table` covers the simulator's own kernels — the
standalone ``ata_tag_probe`` *and* the fused ``ata_probe_rank``
(probe + winner rank + port arbitration, PR 6) — with an analytic
roofline derived from their BlockSpecs: HBM bytes actually streamed
per grid step (the tag state is re-read once per request tile — that
re-read, not the compare, is what bounds both kernels), integer VPU
ops, arithmetic intensity, and the memory/compute-bound time on the
reference chip. Wall time is measured only on a real TPU backend
(``jax.default_backend() == "tpu"``); the interpret path on CPU
validates semantics, not speed, so off-TPU rows report the model only.
"""
import glob
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"
PEAK_FLOPS = 197e12
HBM_BW = 1.2e12          # bytes/s, reference-chip HBM stream rate
PEAK_INT_OPS = 4.9e13    # int32 VPU lanes (no MXU help for equality)

#: Canonical probe-kernel shape (matches benchmarks.kernel_micro):
#: R requests against C caches of S sets x W ways, clusters of G.
KERNEL_SHAPE = {"R": 1024, "C": 16, "S": 8, "W": 64, "G": 4}


def load(mesh="sp"):
    rows = []
    for f in sorted(glob.glob(str(RESULTS / f"*__{mesh}.json"))):
        r = json.load(open(f))
        rows.append(r)
    return rows


def fraction(r):
    if r["status"] != "ok":
        return None
    t = r["roofline"]
    ideal = t["model_flops_global"] / r["chips"] / PEAK_FLOPS
    bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return ideal / bound if bound else None


def table(mesh="sp"):
    rows = []
    for r in load(mesh):
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], "SKIP", r.get("reason", "")))
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], "ERR", r.get("error", "")[:60]))
            continue
        t = r["roofline"]
        frac = fraction(r)
        rows.append((
            r["arch"], r["shape"], t["dominant"],
            f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
            f"{t['collective_s']:.4f}",
            f"{t['useful_flops_ratio']:.2f}" if t["useful_flops_ratio"] else "-",
            f"{frac:.3f}" if frac else "-",
            f"{r['memory']['peak_estimate_gb']:.1f}GB",
        ))
    return rows


def kernel_model(name, shape=None):
    """Analytic (bytes, int_ops) per call for a probe kernel.

    Traffic follows the kernel BlockSpecs, not the array sizes: both
    kernels hold the tag state resident per program but the grid walks
    request tiles, so tags/valid(/dirty) stream from HBM once per tile
    — ``R/br`` times per call. Ops count the one-hot set gather
    (2 ops per (request, cache, set, way) lane: select + max) plus the
    comparator group and per-request reductions.
    """
    s = dict(KERNEL_SHAPE, **(shape or {}))
    R, C, S, W = s["R"], s["C"], s["S"], s["W"]
    state = C * S * W
    if name == "ata_tag_probe":
        from repro.kernels.ata_tag_probe import DEFAULT_BC, DEFAULT_BR
        br, bc = min(DEFAULT_BR, R), min(DEFAULT_BC, C)
        tiles = (R // br) * (C // bc)
        bytes_ = (tiles * (bc * S * W) * (4 + 1)   # tags + valid
                  + (C // bc) * R * 8              # set_idx + qtag
                  + R * C * 5)                     # hits + ways out
        ops = R * C * W * (2 * S + 3)
    elif name == "ata_probe_rank":
        from repro.kernels.ata_probe_rank import DEFAULT_BR
        br = min(DEFAULT_BR, R)
        bytes_ = ((R // br) * state * (4 + 1 + 1)  # tags+valid+dirty
                  + R * 19                         # 6 request vectors in
                  + R * 14 + C * 4)                # 5 outputs + counts
        # probe over the full cluster + winner one-hot rank + the
        # grid-carried port-arbitration prefix counts
        ops = R * C * W * (2 * S + 3) + R * C * (s["G"] + 6)
    else:
        raise ValueError(f"unknown kernel {name!r}")
    return bytes_, ops


def kernel_table(shape=None):
    """Rows: (kernel, bytes, ops, intensity, mem_s, comp_s, bound,
    measured_us or None)."""
    import jax
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    for name in ("ata_tag_probe", "ata_probe_rank"):
        bytes_, ops = kernel_model(name, shape)
        mem_s = bytes_ / HBM_BW
        comp_s = ops / PEAK_INT_OPS
        bound = "memory" if mem_s >= comp_s else "compute"
        measured = _time_kernel(name, shape) if on_tpu else None
        rows.append((name, bytes_, ops, ops / bytes_, mem_s, comp_s,
                     bound, measured))
    return rows


def _time_kernel(name, shape=None, iters=20):
    """Median wall us/call of the compiled Pallas kernel (TPU only)."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    s = dict(KERNEL_SHAPE, **(shape or {}))
    R, C, S, W, G = s["R"], s["C"], s["S"], s["W"], s["G"]
    rng = np.random.default_rng(0)
    tags = jnp.asarray(rng.integers(0, 4096, (C, S, W)), jnp.int32)
    valid = jnp.asarray(rng.random((C, S, W)) < 0.7)
    qtag = jnp.asarray(rng.integers(0, 4096, R), jnp.int32)
    set_idx = jnp.asarray(rng.integers(0, S, R), jnp.int32)
    if name == "ata_tag_probe":
        call = lambda: ops.ata_probe(set_idx, qtag, tags, valid,  # noqa: E731
                                     impl="pallas")
    else:
        core = jnp.asarray(rng.integers(0, C, R), jnp.int32)
        cbase = (core // G) * G
        deny = jnp.asarray(rng.random(R) < 0.2)
        dirty = jnp.asarray(valid & (rng.random((C, S, W)) < 0.2))
        call = lambda: ops.ata_probe_rank(                        # noqa: E731
            set_idx, qtag, core, cbase, deny, tags, valid, dirty,
            cluster_size=G, impl="pallas")
    jax.block_until_ready(call())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2] * 1e6


def print_kernel_table(shape=None):
    s = dict(KERNEL_SHAPE, **(shape or {}))
    print(f"\n=== roofline: probe kernels (R={s['R']} C={s['C']} "
          f"S={s['S']} W={s['W']}) ===")
    print(f"{'kernel':16s} {'KB':>8s} {'ops':>10s} {'ops/B':>6s} "
          f"{'mem_us':>8s} {'comp_us':>8s} {'bound':8s} {'meas_us':>8s}")
    for name, b, o, ai, mem_s, comp_s, bound, meas in kernel_table(shape):
        meas_col = f"{meas:>8.1f}" if meas is not None else f"{'-':>8s}"
        print(f"{name:16s} {b / 1024:>8.1f} {o:>10d} {ai:>6.1f} "
              f"{mem_s * 1e6:>8.2f} {comp_s * 1e6:>8.2f} {bound:8s} "
              f"{meas_col}")


def main():
    print_kernel_table()
    for mesh, name in (("sp", "single-pod 16x16"), ("mp", "multi-pod 2x16x16")):
        print(f"\n=== roofline: {name} ===")
        print(f"{'arch':22s} {'shape':12s} {'bound':10s} {'comp_s':>8s} "
              f"{'mem_s':>8s} {'coll_s':>8s} {'useful':>6s} {'frac':>6s} {'peak':>8s}")
        for row in table(mesh):
            if row[2] in ("SKIP", "ERR"):
                print(f"{row[0]:22s} {row[1]:12s} {row[2]:10s} {row[3][:50]}")
            else:
                print(f"{row[0]:22s} {row[1]:12s} {row[2]:10s} "
                      f"{row[3]:>8s} {row[4]:>8s} {row[5]:>8s} {row[6]:>6s} "
                      f"{row[7]:>6s} {row[8]:>8s}")


if __name__ == "__main__":
    main()
