#!/usr/bin/env python
"""Nightly benchmark trend tracking: drift across a report history.

The nightly CI job persists every full-fidelity sensitivity report
(``benchmarks.run --full --report-json``) into a ``bench_history/``
directory (one ``<date>.json`` per run, carried across runs by an
actions cache and uploaded as the ``bench-history`` artifact). This
script reads that directory and

  * prints a per-cell IPC time series as CSV (``--csv PATH`` or
    stdout),
  * writes a markdown trend summary (``--markdown PATH``): latest
    value, trailing median, and relative drift per cell — solo cells,
    mix weighted speedups, and noc topology cells alike,
  * flags cells whose *latest* value drifts beyond ``--rtol`` from the
    trailing median of the earlier runs (a regression the per-PR gate
    can miss when it creeps in below the per-run tolerance).

Exit code is 0 unless ``--strict`` is passed and drift was flagged —
trend tracking is informational by default so one noisy nightly cannot
redden the calendar.

    PYTHONPATH=src python scripts/bench_trend.py bench_history \
        [--markdown TREND.md] [--csv trend.csv] [--rtol 0.05] [--strict]

Reports are ordered by filename (ISO dates sort correctly); at least
two are needed for drift, one still produces the tables. Simulator
throughput reports (``benchmarks.sim_speed``, ``"kind": "simspeed"``)
ride the same history directory: their per-backend rounds/sec and the
fused-speedup ratio become ``simspeed`` series rows. Serving-engine
reports (``benchmarks.fig_serving_scale``, ``"kind": "serving"``)
likewise: per (shards x mix x policy x slots) cell, hit rate, modeled
p99 latency, and host replay throughput become ``serving`` series
rows, and the batched-admission req/s-ratio headlines (modeled +
wall, B=max vs B=1) get their own series. Observability captures
(``benchmarks.telemetry_capture``, ``"kind": "telemetry"``) contribute
histogram-derived latency quantiles (the serving p50/p99 are exact
quantile reads) and hit rates as ``telemetry`` series rows. A missing
or empty history directory produces a "no history yet" markdown and
exit 0 — the first nightly run is not a failure.
"""
import argparse
import json
import os
import statistics
import sys
from typing import Dict, List, Tuple


def _cell_series(reports: List[Tuple[str, dict]]
                 ) -> Dict[tuple, List[Tuple[str, float]]]:
    """{(section, *cell key, metric): [(run name, value), ...]}."""
    series: Dict[tuple, List[Tuple[str, float]]] = {}

    def add(run, section, key, metric, value):
        series.setdefault((section,) + key + (metric,), []) \
            .append((run, float(value)))

    for run, rep in reports:
        if rep.get("kind") == "simspeed":
            # throughput reports: per-backend rounds/sec (absolute —
            # informative across comparable runners) + the
            # machine-portable fused speedup ratio
            for c in rep.get("cells", ()):
                add(run, "simspeed", (c["backend"],), "rounds_per_sec",
                    c["rounds_per_sec"])
            ratio = rep.get("headline", {}).get("fused_speedup")
            if ratio is not None:
                add(run, "simspeed", ("lax/lax_unfused",),
                    "fused_speedup", ratio)
            continue
        if rep.get("kind") == "telemetry":
            # observability smoke captures: histogram-derived latency
            # quantiles (serving p99 is an *exact* quantile read; the
            # sim one is a log2-bucket upper edge) + hit rates, so the
            # latency story trends alongside the throughput one
            sim = rep.get("sim", {})
            for metric in ("l1_hit_rate", "p99_latency_bucket"):
                if sim.get(metric) is not None:
                    add(run, "telemetry",
                        (sim.get("arch"), sim.get("noc")), metric,
                        sim[metric])
            srv = rep.get("serving", {})
            for metric in ("hit_rate", "p50_latency", "p99_latency"):
                if srv.get(metric) is not None:
                    add(run, "telemetry",
                        (srv.get("policy"), srv.get("mix"),
                         srv.get("shards")), metric, srv[metric])
            continue
        if rep.get("kind") == "serving":
            # serving-engine reports: deterministic quality metrics
            # (hit rate, modeled p99) + host-dependent replay
            # throughput, per (shards x mix x policy x slots) cell
            # (pre-batching reports carry no "slots" key: B=1), plus
            # the machine-portable batched req/s-ratio headline
            for c in rep.get("cells", ()):
                key = (c["shards"], c["mix"], c["policy"],
                       c.get("slots", 1))
                add(run, "serving", key, "hit_rate", c["hit_rate"])
                add(run, "serving", key, "p99_latency",
                    c["p99_latency"])
                add(run, "serving", key, "throughput_rps",
                    c["throughput_rps"])
            head = rep.get("headline", {})
            b = head.get("batched_slots")
            for metric in ("batched_model_speedup",
                           "batched_wall_speedup"):
                if head.get(metric) is not None:
                    add(run, "serving", (f"B{b}/B1",), metric,
                        head[metric])
            continue
        for c in rep.get("cells", ()):
            add(run, "solo", (c["arch"], c["knob"], c["value"]), "ipc",
                c["ipc"])
        for c in rep.get("mix", {}).get("cells", ()):
            add(run, "mix", (c["mix"], c["arch"]), "weighted_speedup",
                c["weighted_speedup"])
        for c in rep.get("noc", {}).get("cells", ()):
            add(run, "noc", (c["arch"], c["noc"], c["noc_bw"]), "ipc",
                c["ipc"])
    return series


def load_history(directory: str) -> List[Tuple[str, dict]]:
    """Parse every report JSON under ``directory``, oldest first.

    A missing or not-yet-a-directory history (the first nightly run on
    a fresh cache) is an empty history, not a crash.
    """
    try:
        names = sorted(n for n in os.listdir(directory)
                       if n.endswith(".json"))
    except (FileNotFoundError, NotADirectoryError):
        print(f"no history directory at {directory}", file=sys.stderr)
        return []
    out = []
    for name in names:
        path = os.path.join(directory, name)
        try:
            with open(path) as f:
                rep = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"skipping unreadable report {path}: {e}",
                  file=sys.stderr)
            continue
        if "cells" not in rep and rep.get("kind") != "telemetry":
            print(f"skipping non-report JSON {path}", file=sys.stderr)
            continue
        out.append((os.path.splitext(name)[0], rep))
    return out


def trend_rows(series: Dict[tuple, List[Tuple[str, float]]],
               rtol: float) -> List[dict]:
    """One row per cell: latest, trailing median, drift, flagged."""
    rows = []
    for key in sorted(series, key=str):
        points = series[key]
        latest_run, latest = points[-1]
        earlier = [v for _, v in points[:-1]]
        if earlier:
            med = statistics.median(earlier)
            if med:
                drift = (latest - med) / abs(med)
            else:
                # zero median: no drift if still zero, else unbounded
                drift = 0.0 if latest == 0 else float("inf")
            flagged = abs(drift) > rtol
        else:
            med, drift, flagged = latest, 0.0, False
        rows.append({
            "key": key, "runs": len(points), "latest_run": latest_run,
            "latest": latest, "median": med, "drift": drift,
            "flagged": flagged,
        })
    return rows


def to_csv(series: Dict[tuple, List[Tuple[str, float]]]) -> str:
    lines = ["section,cell,metric,run,value"]
    for key in sorted(series, key=str):
        section, *cell, metric = key
        label = "/".join(str(c) for c in cell)
        for run, value in series[key]:
            lines.append(f"{section},{label},{metric},{run},{value!r}")
    return "\n".join(lines) + "\n"


def to_markdown(rows: List[dict], rtol: float, n_runs: int) -> str:
    flagged = [r for r in rows if r["flagged"]]
    lines = [
        "# Benchmark trend report",
        "",
        f"{n_runs} run(s), {len(rows)} tracked cells, drift tolerance "
        f"±{rtol:.0%} vs the trailing median.",
        "",
        (f"**{len(flagged)} cell(s) drifted beyond tolerance.**"
         if flagged else "No cell drifted beyond tolerance."),
        "",
        "| section | cell | metric | runs | median | latest | drift |",
        "|---|---|---|---|---|---|---|",
    ]
    # flagged rows first, then the rest, so regressions lead the table
    for r in flagged + [r for r in rows if not r["flagged"]]:
        section, *cell, metric = r["key"]
        label = "/".join(str(c) for c in cell)
        mark = " ⚠" if r["flagged"] else ""
        lines.append(
            f"| {section} | {label} | {metric} | {r['runs']} "
            f"| {r['median']:.3f} | {r['latest']:.3f} "
            f"| {r['drift']:+.1%}{mark} |")
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("history", help="directory of dated report JSONs")
    ap.add_argument("--rtol", type=float, default=0.05,
                    help="flag |latest - median|/median beyond this "
                    "(default 5%%)")
    ap.add_argument("--markdown", metavar="PATH",
                    help="write the markdown trend summary here")
    ap.add_argument("--csv", metavar="PATH",
                    help="write the full time-series CSV here "
                    "(default: stdout)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when any cell is flagged")
    args = ap.parse_args()

    reports = load_history(args.history)
    if not reports:
        # first nightly on a fresh cache: emit valid (empty) outputs
        # and succeed — "no history yet" is a state, not a failure
        print(f"no reports found under {args.history}", file=sys.stderr)
        if args.markdown:
            with open(args.markdown, "w") as f:
                f.write("# Benchmark trend report\n\n"
                        "No history yet — this is the first tracked "
                        "run; trends appear once a report lands in "
                        f"`{args.history}`.\n")
        empty_csv = "section,cell,metric,run,value\n"
        if args.csv:
            with open(args.csv, "w") as f:
                f.write(empty_csv)
        else:
            sys.stdout.write(empty_csv)
        return 0
    series = _cell_series(reports)
    rows = trend_rows(series, args.rtol)

    csv = to_csv(series)
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(csv)
    else:
        sys.stdout.write(csv)
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(to_markdown(rows, args.rtol, len(reports)))

    flagged = [r for r in rows if r["flagged"]]
    for r in flagged:
        section, *cell, metric = r["key"]
        print(f"drift ⚠ {section} {'/'.join(map(str, cell))} {metric}: "
              f"median {r['median']:.3f} -> latest {r['latest']:.3f} "
              f"({r['drift']:+.1%})", file=sys.stderr)
    print(f"trend: {len(reports)} runs, {len(rows)} cells, "
          f"{len(flagged)} flagged (rtol {args.rtol:.0%})",
          file=sys.stderr)
    return 1 if (flagged and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
