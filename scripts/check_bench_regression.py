#!/usr/bin/env python
"""CI benchmark-regression gate.

Compares a freshly produced sensitivity report (``benchmarks.run
--report-json``) against the committed baseline and exits non-zero on
per-cell drift beyond the tolerance (solo IPC; mix weighted speedup
when both reports carry the ``mix`` section) or executable-count
growth:

    PYTHONPATH=src python scripts/check_bench_regression.py \
        benchmarks/baselines/sensitivity_rounds96.json \
        BENCH_sensitivity.json [--ipc-rtol 0.10]

The report schema is versioned (``repro.core.report.SCHEMA_VERSION``)
and the gate is forward-compatible: a candidate at a *newer* schema
(e.g. one that grew the multi-tenant ``mix`` section) is gated on the
sections the older baseline carries instead of failing on unknown
keys; a candidate at an older schema than the baseline fails.

The same gate covers the simulator-throughput reports of
``benchmarks.sim_speed`` (``"kind": "simspeed"``): when the baseline
declares that kind, the comparison dispatches to
``repro.core.report.compare_simspeed``, which gates the
machine-portable fused-vs-unfused speedup *ratio* (``--speedup-rtol``,
one-sided) rather than host-dependent absolute rounds/sec
(``--rps-rtol`` opt-in for same-runner setups):

    PYTHONPATH=src python scripts/check_bench_regression.py \
        benchmarks/baselines/simspeed_rounds64.json \
        BENCH_simspeed.json [--speedup-rtol 0.30]

Serving-engine reports (``benchmarks.fig_serving_scale``,
``"kind": "serving"``) dispatch to
``repro.core.report.compare_serving``: per
(shards x mix x policy x slots) cell, probe-message counts gate
*exactly* (the stream is seeded and the engine integer-deterministic)
and hit rate within ``--hit-rtol``; the batched-admission headline —
worst-cell modeled requests-per-kcycle ratio, B=max vs B=1 — gates
one-sided against both the absolute >= 1.5x acceptance floor and the
baseline ratio minus ``--batched-rtol`` (the ratio is deterministic,
hence machine-portable like the simspeed speedup gate);
host-dependent replay throughput is never gated per cell, and the
wall-clock batched ratio only with the opt-in ``--wall-rtol``:

    PYTHONPATH=src python scripts/check_bench_regression.py \
        benchmarks/baselines/serving_rounds512.json \
        BENCH_serving.json [--hit-rtol 0.005] [--batched-rtol 0.15]

To update the baseline after an *intentional* performance or model
change, regenerate it with the same configuration CI uses and commit:

    PYTHONPATH=src python -m benchmarks.run --rounds 96 \
        --report-json benchmarks/baselines/sensitivity_rounds96.json
    PYTHONPATH=src python -m benchmarks.sim_speed --rounds 64 \
        --json benchmarks/baselines/simspeed_rounds64.json
    PYTHONPATH=src python -m benchmarks.fig_serving_scale --rounds 512 \
        --json benchmarks/baselines/serving_rounds512.json
"""
import argparse
import sys

from repro.core.report import (compare_reports, compare_serving,
                               compare_simspeed, load_report)


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("baseline", help="committed baseline report JSON")
    ap.add_argument("candidate", help="freshly produced report JSON")
    ap.add_argument("--ipc-rtol", type=float, default=0.10,
                    help="allowed per-cell IPC drift (default 10%%)")
    ap.add_argument("--speedup-rtol", type=float, default=0.30,
                    help="allowed one-sided fused-speedup-ratio drop "
                    "for simspeed reports (default 30%%)")
    ap.add_argument("--rps-rtol", type=float, default=None,
                    help="gate absolute rounds/sec too (simspeed; "
                    "off by default — host-dependent)")
    ap.add_argument("--hit-rtol", type=float, default=0.005,
                    help="allowed per-cell hit-rate drift for serving "
                    "reports (default 0.5%%; probe counts gate exactly)")
    ap.add_argument("--latency-rtol", type=float, default=None,
                    help="gate modeled p99 latency too (serving; off "
                    "by default — moves with the cost model)")
    ap.add_argument("--batched-rtol", type=float, default=0.15,
                    help="allowed one-sided batched modeled-speedup "
                    "drop vs baseline (serving; the absolute 1.5x "
                    "floor always applies; default 15%%)")
    ap.add_argument("--wall-rtol", type=float, default=None,
                    help="gate the batched wall-clock speedup ratio "
                    "too (serving; off by default — host-dependent)")
    args = ap.parse_args()

    baseline = load_report(args.baseline)
    candidate = load_report(args.candidate)
    if baseline.get("kind") == "serving":
        failures = compare_serving(baseline, candidate,
                                   hit_rtol=args.hit_rtol,
                                   latency_rtol=args.latency_rtol,
                                   batched_rtol=args.batched_rtol,
                                   wall_rtol=args.wall_rtol)
        if failures:
            print(f"serving regression gate FAILED "
                  f"({len(failures)} finding(s)):", file=sys.stderr)
            for f in failures:
                print(f"  - {f}", file=sys.stderr)
            print("(intentional change? regenerate the baseline — see "
                  "--help)", file=sys.stderr)
            return 1
        ratio = candidate.get("headline", {}) \
            .get("batched_model_speedup")
        batched = (f", batched speedup {ratio:.2f}x"
                   if ratio is not None else "")
        print(f"serving regression gate OK: "
              f"{len(baseline['cells'])} cells, probe messages exact, "
              f"hit rate within ±{args.hit_rtol:.1%}{batched}")
        return 0
    if baseline.get("kind") == "simspeed":
        failures = compare_simspeed(baseline, candidate,
                                    speedup_rtol=args.speedup_rtol,
                                    rps_rtol=args.rps_rtol)
        if failures:
            print(f"simspeed regression gate FAILED "
                  f"({len(failures)} finding(s)):", file=sys.stderr)
            for f in failures:
                print(f"  - {f}", file=sys.stderr)
            print("(intentional change? regenerate the baseline — see "
                  "--help)", file=sys.stderr)
            return 1
        ratio = candidate.get("headline", {}).get("fused_speedup")
        print(f"simspeed regression gate OK: "
              f"{len(baseline['cells'])} backends present, fused "
              f"speedup {ratio:.3f}x (floor "
              f"{baseline['headline']['fused_speedup'] * (1 - args.speedup_rtol):.3f}x)")
        return 0
    if candidate.get("schema") != baseline.get("schema"):
        print(f"note: forward-compatible compare — baseline schema "
              f"{baseline.get('schema')}, candidate schema "
              f"{candidate.get('schema')}; gating on the baseline's "
              "sections only", file=sys.stderr)
    failures = compare_reports(baseline, candidate,
                               ipc_rtol=args.ipc_rtol)
    if failures:
        print(f"benchmark regression gate FAILED "
              f"({len(failures)} finding(s)):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        print("(intentional change? regenerate the baseline — see "
              "--help)", file=sys.stderr)
        return 1
    n = len(baseline["cells"])
    print(f"benchmark regression gate OK: {n} cells within "
          f"±{args.ipc_rtol:.0%} IPC, executables "
          f"{candidate['sweep']['n_executables']} <= "
          f"{baseline['sweep']['n_executables']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
