"""Calibration harness: suite summary vs paper targets."""
import sys, time
import numpy as np
from repro.core import (APPS, HIGH_LOCALITY, LOW_LOCALITY, run_suite,
                        normalized_ipc, geomean)

kpa = int(sys.argv[1]) if len(sys.argv) > 1 else 1
t0 = time.time()
suite = run_suite(kernels_per_app=kpa)
ipc = normalized_ipc(suite)
print(f"{'app':10s} {'cls':4s} | {'ATA':>6s} {'dec':>6s} {'rem':>6s} | "
      f"{'L1lat A':>8s} {'L1lat D':>8s} | {'HR p':>5s} {'HR a':>5s} {'HR d':>5s}")
for app in list(HIGH_LOCALITY) + list(LOW_LOCALITY):
    r = suite[app]
    lat = {a: r[a].l1_latency / r["private"].l1_latency for a in r}
    print(f"{app:10s} {'HI' if APPS[app].high_locality else 'LO':4s} | "
          f"{ipc[app]['ata']:6.3f} {ipc[app]['decoupled']:6.3f} {ipc[app]['remote']:6.3f} | "
          f"{lat['ata']:8.3f} {lat['decoupled']:8.3f} | "
          f"{r['private'].l1_hit_rate:5.2f} {r['ata'].l1_hit_rate:5.2f} {r['decoupled'].l1_hit_rate:5.2f}")
hi_ata = geomean([ipc[a]["ata"] for a in HIGH_LOCALITY])
lo_ata = geomean([ipc[a]["ata"] for a in LOW_LOCALITY])
lo_dec = geomean([ipc[a]["decoupled"] for a in LOW_LOCALITY])
lat_a = np.mean([suite[a]["ata"].l1_latency / suite[a]["private"].l1_latency for a in APPS])
lat_d = np.mean([suite[a]["decoupled"].l1_latency / suite[a]["private"].l1_latency for a in APPS])
lat_dmax = max(suite[a]["decoupled"].l1_latency / suite[a]["private"].l1_latency for a in APPS)
print(f"\nATA hi-loc IPC gain : {100*(hi_ata-1):+6.1f}%   (paper +12.0%)")
print(f"ATA lo-loc IPC gain : {100*(lo_ata-1):+6.1f}%   (paper ~0%, no impairment)")
print(f"ATA/dec lo-loc      : {100*(lo_ata/lo_dec-1):+6.1f}%   (paper +22.9%)")
print(f"L1 lat: dec {100*(lat_d-1):+6.1f}% (paper +67.2%, max {lat_dmax:.2f}x vs 2.74x) | ata {100*(lat_a-1):+6.1f}% (paper +6.0%)")
print(f"[{time.time()-t0:.0f}s]")
