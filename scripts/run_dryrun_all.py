"""Run every (arch x shape x mesh) dry-run cell, one subprocess per cell
(jax locks the host-device count per process). Cells already recorded in
results/dryrun/ are skipped unless --force. Order: one representative
cell per risk class first (fail fast), then all single-pod, then
multi-pod."""
import argparse
import json
import pathlib
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"

ARCHS = ["rwkv6-3b", "qwen3-0.6b", "qwen1.5-4b", "nemotron-4-15b",
         "stablelm-12b", "granite-moe-3b-a800m", "granite-moe-1b-a400m",
         "recurrentgemma-9b", "whisper-tiny", "chameleon-34b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

PREFLIGHT = [("rwkv6-3b", "long_500k", False),
             ("whisper-tiny", "train_4k", False),
             ("granite-moe-1b-a400m", "train_4k", False),
             ("recurrentgemma-9b", "decode_32k", False),
             ("qwen1.5-4b", "decode_32k", False),
             ("chameleon-34b", "train_4k", True)]


def cells():
    seen = set()
    for c in PREFLIGHT:
        seen.add(c)
        yield c
    for mp in (False, True):
        for a in ARCHS:
            for s in SHAPES:
                c = (a, s, mp)
                if c not in seen:
                    yield c


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)
    for arch, shape, mp in cells():
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
        out = RESULTS / f"{tag}.json"
        if out.exists() and not args.force:
            st = json.loads(out.read_text()).get("status")
            if st in ("ok", "skipped"):
                print(f"[sweep] {tag}: cached ({st})", flush=True)
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        try:
            r = subprocess.run(
                cmd, cwd=ROOT, timeout=args.timeout,
                env={**__import__("os").environ,
                     "PYTHONPATH": str(ROOT / "src")},
                capture_output=True, text=True)
            tail = (r.stdout or "").strip().splitlines()
            print(f"[sweep] {tag}: {tail[-1] if tail else r.returncode} "
                  f"({time.time()-t0:.0f}s)", flush=True)
            if r.returncode != 0 and not out.exists():
                out.write_text(json.dumps(
                    {"arch": arch, "shape": shape, "status": "error",
                     "error": (r.stderr or "")[-3000:]}))
        except subprocess.TimeoutExpired:
            out.write_text(json.dumps(
                {"arch": arch, "shape": shape, "status": "error",
                 "error": f"timeout {args.timeout}s"}))
            print(f"[sweep] {tag}: TIMEOUT", flush=True)


if __name__ == "__main__":
    main()
