"""Pluggable architecture policies for the cache-hierarchy simulator.

Public API:
  ArchPolicy, L1Outcome, RequestBatch — the policy interface (base.py)
  register_arch / get_arch / registered_archs — the policy registry
  PAPER_ARCHITECTURES — the four architectures the paper compares

The four paper architectures plus two extension variants register on
import; external code adds more with::

    from repro.core.arch import ArchPolicy, register_arch

    @dataclasses.dataclass(frozen=True)
    class MyPolicy(ArchPolicy):
        name: str = "mine"
        def l1_stage(self, geom, l1, reqs, t, *, backend="lax"): ...

    register_arch(MyPolicy())

after which ``simulate("mine", trace)`` just works.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.arch.base import (TAG_CHECK, ArchPolicy, L1Outcome,
                                  RequestBatch)
from repro.core.arch.private import PrivatePolicy
from repro.core.arch.remote import RemotePolicy
from repro.core.arch.decoupled import DecoupledPolicy
from repro.core.arch.ata import AtaPolicy
from repro.core.arch.ata_bypass import AtaBypassPolicy
from repro.core.arch.ciao import CiaoPolicy
from repro.core.arch.victim import VictimPolicy
from repro.core.tagarray import ReplacementPolicy

#: The paper's comparison set (Figs. 8–10, Table I) — a stable subset of
#: the registry; figures iterate this, not every registered variant.
PAPER_ARCHITECTURES: Tuple[str, ...] = ("private", "remote", "decoupled",
                                        "ata")

_REGISTRY: Dict[str, ArchPolicy] = {}


def register_arch(policy: ArchPolicy, *, overwrite: bool = False) -> ArchPolicy:
    """Add a policy to the registry under ``policy.name``."""
    if not isinstance(policy, ArchPolicy):
        raise TypeError(f"expected an ArchPolicy, got {type(policy)!r}")
    if policy.name in _REGISTRY and not overwrite:
        raise ValueError(f"architecture {policy.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[policy.name] = policy
    return policy


def get_arch(name: str) -> ArchPolicy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown architecture {name!r}; registered: "
            f"{registered_archs()}") from None


def registered_archs() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


register_arch(PrivatePolicy())
register_arch(RemotePolicy())
register_arch(DecoupledPolicy())
register_arch(AtaPolicy())
register_arch(AtaBypassPolicy())
register_arch(AtaPolicy(name="ata_fifo",
                        replacement=ReplacementPolicy.FIFO))
# Contention-policy zoo: CIAO-style throttling stacks with the private
# family, the victim tag buffer with the ATA family.
register_arch(CiaoPolicy())
register_arch(VictimPolicy())

__all__ = [
    "TAG_CHECK", "ArchPolicy", "L1Outcome", "RequestBatch",
    "PrivatePolicy", "RemotePolicy", "DecoupledPolicy", "AtaPolicy",
    "AtaBypassPolicy", "CiaoPolicy", "VictimPolicy",
    "PAPER_ARCHITECTURES", "register_arch", "get_arch",
    "registered_archs",
]
