"""CIAO-style interference-aware throttling (arXiv 1805.07718).

CIAO observes that a few cache-thrashing warps can destroy a shared
L1's usefulness for everyone: their streaming fills evict lines other
lanes were still reusing, and the resulting refill traffic contends on
the NoC. Its remedy is to *detect* the thrashing lanes and throttle
them — their requests are redirected around the L1 (straight to L2,
without filling) and slightly deferred, so well-behaved lanes keep
their working sets.

The detector here mirrors the dead-victim predictor used by
``ata_bypass``, but accumulated per core over time in the ``thrash``
TagState extension (see ``tagarray``): every miss whose replacement
victim was never re-touched after its own install (``last == born``)
bumps the issuing core's counter; every round the counter decays by
``thrash_decay``. A core whose counter sits at or above
``thrash_threshold`` at the start of a round is *thrashing*: its misses
that round bypass the L1 fill and pay ``throttle_cycles`` extra before
L2 dispatch (the deferral). Hits are never throttled — a thrashing
core's reused lines still count.

``thrash_threshold <= 0`` disables the scheme entirely — the policy is
then bit-exact with :class:`~repro.core.arch.private.PrivatePolicy`
(counters are not even updated); a hypothesis test asserts this.
``stack_key`` is ``"private"``: CIAO shares the private round dataflow,
so (private, ciao) grids compile one executable.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import tagarray
from repro.core.arch.base import L1Outcome, RequestBatch
from repro.core.arch.private import PrivatePolicy
from repro.core.geometry import GpuGeometry


@dataclasses.dataclass(frozen=True)
class CiaoPolicy(PrivatePolicy):
    name: str = "ciao"
    track_thrash: bool = True
    thrash_threshold: int = 4    # counter level that marks a lane thrashing
    thrash_decay: int = 1        # per-round counter decay
    thrash_cap: int = 32         # counter ceiling (bounds re-enable lag)
    throttle_cycles: float = 16.0  # deferral added before L2 dispatch

    @property
    def stack_key(self) -> str:
        # Same round dataflow as the private baseline: one executable
        # serves (private, ciao) grids behind a traced policy index.
        return "private"

    def l1_stage(self, geom: GpuGeometry, l1: tagarray.TagState,
                 reqs: RequestBatch, t, *,
                 backend: str = "lax") -> L1Outcome:
        out = super().l1_stage(geom, l1, reqs, t, backend=backend)
        # Disabled (threshold <= 0) or run without the thrash extension:
        # degenerate to the private baseline bit-exactly.
        if self.thrash_threshold <= 0 or l1["thrash"].shape[0] == 0:
            return out
        prev = out.l1["thrash"]                       # (C,) start-of-round
        throttled = (prev[reqs.core] >= self.thrash_threshold) & out.go_l2

        # Dead-victim detection on the fills that will actually happen
        # (throttled lanes bypass, so they kill no victim).
        dead_fill = (out.go_l2 & ~throttled
                     & tagarray.dead_victim(out.l1, out.fill_cache,
                                            out.fill_set, reqs.addr,
                                            policy=self.replacement))

        per_core = jnp.zeros_like(prev).at[reqs.core].add(
            dead_fill.astype(jnp.int32))
        thrash = jnp.clip(prev + per_core - self.thrash_decay,
                          0, self.thrash_cap)
        return out._replace(
            l1=dict(out.l1, thrash=thrash),
            pre_l2=out.pre_l2 + jnp.where(throttled,
                                          self.throttle_cycles, 0.0),
            bypass_fill=throttled,
        )
