"""ATA + interference-aware fill bypass (CIAO-style, arXiv 1805.07718).

CIAO observes that streaming (low-reuse) requests thrash a shared L1:
every fill they trigger evicts a line some core was still using, and the
fill/write-back traffic they generate contends with useful transfers.
The detector here is *dead-victim* prediction: if the replacement victim
in the target set was never re-touched after its own install
(``last == born``), the set is absorbing streaming traffic — the
incoming line is predicted equally dead, so the L2 return is forwarded
straight to the core and the L1 fill is skipped. Hits, remote
transfers, and fills over reused victims behave exactly like the base
ATA policy.

The paper's Table-I tension is preserved: the bypass trades ~1% L1 hit
rate for a double-digit NoC flit reduction on stream-heavy apps (HS3D,
sradv1), because skipped fills also skip dirty write-backs.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import tagarray
from repro.core.arch.ata import AtaPolicy
from repro.core.arch.base import L1Outcome, RequestBatch
from repro.core.geometry import GpuGeometry


@dataclasses.dataclass(frozen=True)
class AtaBypassPolicy(AtaPolicy):
    name: str = "ata_bypass"

    def l1_stage(self, geom: GpuGeometry, l1: tagarray.TagState,
                 reqs: RequestBatch, t, *,
                 backend: str = "lax") -> L1Outcome:
        out = super().l1_stage(geom, l1, reqs, t, backend=backend)
        dead = tagarray.dead_victim(out.l1, out.fill_cache, out.fill_set,
                                    reqs.addr, policy=self.replacement)
        # only L2-bound misses bypass; remote hits still replicate locally
        # (they are proven-shared lines, the opposite of streaming data).
        return out._replace(bypass_fill=out.go_l2 & dead)
