"""ATA + per-core victim tag buffer (shared-resource survey, arXiv
1803.06958 §victim/insertion variants).

A small fully-associative FIFO buffer next to each L1 keeps the tags of
recently evicted lines. On an L1 miss it is probed *before* the
remote/aggregated path (the ``_victim_prefilter`` hook in
:class:`~repro.core.arch.ata.AtaPolicy`): a read that hits a victim
entry is served inside the core's own L1 complex — one extra sequential
tag check (:data:`~repro.core.arch.base.TAG_CHECK` cycles) on top of
the L1 latency — and never enters the remote-port contention group or
crosses the crossbar, even when a peer copy exists. The hit line is
promoted back into the L1 proper, its buffer entry invalidated and
swapped with whatever the promotion evicted. Misses past the buffer
behave exactly like the base ATA policy, and writes keep the paper's
local-only coherence rule (they never hit the buffer).

Entries come from evictions: the policy predicts the shared fill
stage's replacement decision (the same ``probe`` the fill stage runs on
the returned state) and captures the outgoing valid tags. Within a
round, duplicate evictions from one cache resolve last-writer-wins —
the buffer has a single fill port (see ``tagarray.victim_insert``).

``victim_ways=0`` disables the buffer; the policy is then bit-exact
with :class:`~repro.core.arch.ata.AtaPolicy` (a hypothesis test asserts
this). ``stack_key`` is inherited — ``"ata"`` — so the whole ATA family
plus this variant compiles into one stacked executable.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import tagarray
from repro.core.arch.ata import AtaPolicy
from repro.core.arch.base import L1Outcome, RequestBatch
from repro.core.geometry import GpuGeometry


@dataclasses.dataclass(frozen=True)
class VictimPolicy(AtaPolicy):
    name: str = "victim"
    victim_ways: int = 8

    def _victim_prefilter(self, l1: tagarray.TagState, reqs: RequestBatch):
        if tagarray.victim_ways(l1) == 0:
            return None
        hit, _ = tagarray.victim_probe(l1, reqs.core, reqs.addr)
        return hit

    def l1_stage(self, geom: GpuGeometry, l1: tagarray.TagState,
                 reqs: RequestBatch, t, *,
                 backend: str = "lax") -> L1Outcome:
        out = super().l1_stage(geom, l1, reqs, t, backend=backend)
        if tagarray.victim_ways(out.l1) == 0:
            return out
        addr, set_idx = reqs.addr, reqs.set_idx
        state = out.l1

        # Reconstruct the base stage's victim-served mask. ``touch``
        # only moves timestamps/dirty bits, so probing tags on the
        # returned state reproduces the pre-touch local-hit mask, and
        # the buffer arrays were untouched entirely.
        hits, _, _ = tagarray.probe_many(state, reqs.peers, set_idx, addr)
        is_self = (jnp.arange(geom.cluster_size)[None, :]
                   == reqs.self_slot[:, None])
        local_hit = (hits & is_self).any(axis=-1)
        vhit, vslot = tagarray.victim_probe(state, reqs.core, addr)
        vserved = vhit & ~local_hit & ~reqs.is_write

        # Promote back into the L1 proper: the entry leaves the buffer
        # and swaps with the line the promotion evicts.
        state = tagarray.victim_invalidate(state, reqs.core, vslot, vserved)
        _, pway, _ = tagarray.probe(state, reqs.core, set_idx, addr,
                                    policy=self.replacement)
        swap_tag = state["tags"][reqs.core, set_idx, pway]
        swap_valid = state["valid"][reqs.core, set_idx, pway]
        state, promo_wb = tagarray.fill(state, reqs.core, set_idx, pway,
                                        addr, t, vserved)
        state = tagarray.victim_insert(state, reqs.core, swap_tag, t,
                                       vserved & swap_valid)

        # Capture what the shared fill stage will evict on L2/remote
        # returns. It probes the state we return, so predicting its
        # victim way here is exact (up to same-(cache,set) duplicates
        # within the round, which resolve last-writer-wins there too).
        fill_mask = out.go_l2 | out.remote_hits
        if out.bypass_fill is not None:
            fill_mask = fill_mask & ~out.bypass_fill
        _, fway, _ = tagarray.probe(state, out.fill_cache, out.fill_set,
                                    addr, policy=self.replacement)
        ev_tag = state["tags"][out.fill_cache, out.fill_set, fway]
        ev_valid = state["valid"][out.fill_cache, out.fill_set, fway]
        state = tagarray.victim_insert(state, out.fill_cache, ev_tag, t,
                                       fill_mask & ev_valid)

        return out._replace(
            l1=state,
            # promotions of a dirty victim's frame write the old line back
            noc_flits=out.noc_flits
            + jnp.sum(promo_wb) * geom.flits_per_line,
        )
