"""ATA: aggregated tag array probed in parallel at zero added latency.

Only *known* remote hits cross the crossbar; writes are local-only with
dirty-bit L2 diversion [the paper's coherence rule]. The tag-side
filtering — no probe traffic, no speculative data movement — is the
paper's core contention win.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import tagarray
from repro.core.arch.base import TAG_CHECK, ArchPolicy, L1Outcome, RequestBatch
from repro.core.geometry import GpuGeometry
from repro.core.probe import fused_probe_rank


@dataclasses.dataclass(frozen=True)
class AtaPolicy(ArchPolicy):
    name: str = "ata"

    @property
    def stack_key(self) -> str:
        # The whole ATA family (base, FIFO replacement, CIAO-style
        # bypass) shares one round dataflow, so sweeps stack the
        # variants into a single executable behind a traced policy
        # index.
        return "ata"

    def _victim_prefilter(self, l1: tagarray.TagState, reqs: RequestBatch):
        """Hook: mask of requests a victim structure can serve locally.

        Probed on L1 miss *before* the remote path — a hit here is
        served inside the core's own L1 complex (one extra sequential
        tag check) and never enters the remote-port contention group or
        crosses the crossbar. The base policy has no victim structure:
        ``None`` keeps the stage's computation graph untouched.
        """
        return None

    def l1_stage(self, geom: GpuGeometry, l1: tagarray.TagState,
                 reqs: RequestBatch, t, *,
                 backend: str = "lax") -> L1Outcome:
        addr, set_idx = reqs.addr, reqs.set_idx
        # victim prefilter: read misses served by a victim structure
        # (when the subclass provides one) skip the remote path.
        pre = self._victim_prefilter(l1, reqs)
        # aggregated tag array: all cluster tags compared in parallel,
        # zero added latency, zero probe traffic — plus winner pick and
        # remote-port arbitration, fused under the selected backend
        # (repro.core.probe; all backends are bit-exact).
        pr = fused_probe_rank(geom, l1, reqs, pre_served=pre,
                              replacement=self.replacement,
                              backend=backend)
        local_hit, way = pr.local_hit, pr.touch_way
        remote_ok, src_cache = pr.remote_ok, pr.src_cache
        prank, psize = pr.prank, pr.psize
        vserved = (None if pre is None
                   else pre & ~local_hit & ~reqs.is_write)
        # only *actual* remote hits occupy the remote data port — the
        # filtering that is the paper's core contention win.
        occupancy = jnp.where(
            remote_ok, psize.astype(jnp.float32) * geom.svc_port, 0.0)
        served = local_hit | remote_ok
        local_hits = local_hit
        l1_time = jnp.where(
            local_hit, geom.lat_l1 * 1.0,
            jnp.where(remote_ok,
                      geom.lat_l1 + geom.lat_xbar
                      + prank.astype(jnp.float32) * geom.svc_port,
                      float(TAG_CHECK)))
        if vserved is not None:
            served = served | vserved
            local_hits = local_hits | vserved
            l1_time = jnp.where(vserved,
                                geom.lat_l1 + float(TAG_CHECK), l1_time)
        l1 = tagarray.touch(l1, reqs.core, set_idx, way, t, local_hit,
                            set_dirty=reqs.is_write)
        return L1Outcome(
            l1=l1,
            served=served,
            l1_time=l1_time,
            go_l2=~served,
            pre_l2=jnp.full((reqs.n_requests,), float(TAG_CHECK)),
            occupancy=occupancy,
            fill_cache=reqs.core,
            fill_set=set_idx,
            local_hits=local_hits,
            remote_hits=remote_ok,
            noc_flits=jnp.sum(remote_ok) * geom.flits_per_line,
            # only known remote hits put flits on the interconnect —
            # the tag-side filtering that is the paper's core win
            noc_src=jnp.where(remote_ok, src_cache, reqs.core),
            noc_req_flits=remote_ok * (geom.flits_per_line * 1.0),
        )
