"""Baseline architecture: per-core private L1, misses go straight to L2."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import tagarray
from repro.core.arch.base import TAG_CHECK, ArchPolicy, L1Outcome, RequestBatch
from repro.core.geometry import GpuGeometry


@dataclasses.dataclass(frozen=True)
class PrivatePolicy(ArchPolicy):
    name: str = "private"

    def l1_stage(self, geom: GpuGeometry, l1: tagarray.TagState,
                 reqs: RequestBatch, t, *,
                 backend: str = "lax") -> L1Outcome:
        del backend   # no probe chain to lower (ATA-family axis)
        R = reqs.n_requests
        hit, way, _ = tagarray.probe(l1, reqs.core, reqs.set_idx, reqs.addr,
                                     policy=self.replacement)
        l1 = tagarray.touch(l1, reqs.core, reqs.set_idx, way, t, hit,
                            set_dirty=reqs.is_write)
        return L1Outcome(
            l1=l1,
            served=hit,
            l1_time=jnp.where(hit, geom.lat_l1 * 1.0, float(TAG_CHECK)),
            go_l2=~hit,
            pre_l2=jnp.full((R,), float(TAG_CHECK)),
            occupancy=jnp.zeros((R,), jnp.float32),
            fill_cache=reqs.core,
            fill_set=reqs.set_idx,
            local_hits=hit,
            remote_hits=jnp.zeros((R,), bool),
            noc_flits=0.0,
        )
