"""Remote sharing: broadcast probes to cluster peers [Dublish'16, Ibrahim'19].

A local miss queries every peer L1 in the cluster; the probe service
queue and NoC load delay sit on the critical path even when the line
ends up coming from L2.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import tagarray
from repro.core.arch.base import TAG_CHECK, ArchPolicy, L1Outcome, RequestBatch
from repro.core.contention import group_rank
from repro.core.geometry import GpuGeometry


@dataclasses.dataclass(frozen=True)
class RemotePolicy(ArchPolicy):
    name: str = "remote"

    def l1_stage(self, geom: GpuGeometry, l1: tagarray.TagState,
                 reqs: RequestBatch, t, *,
                 backend: str = "lax") -> L1Outcome:
        del backend   # no probe chain to lower (ATA-family axis)
        addr, set_idx = reqs.addr, reqs.set_idx
        hit, way, _ = tagarray.probe(l1, reqs.core, set_idx, addr,
                                     policy=self.replacement)
        miss = ~hit
        # broadcast probes: each miss queries all peers; probe service
        # queue per cluster + NoC load delay sit on the critical path.
        rank, n_miss = group_rank(reqs.cluster, miss, geom.n_clusters)
        probe_flits = n_miss.astype(jnp.float32) * (geom.cluster_size - 1)
        noc_delay = probe_flits / geom.noc_bw
        probe_wait = (geom.lat_probe + rank.astype(jnp.float32)
                      * geom.svc_probe + noc_delay)
        rhits, _, _ = tagarray.probe_many(l1, reqs.peers, set_idx, addr)
        rhits = rhits & (jnp.arange(geom.cluster_size)[None, :]
                         != reqs.self_slot[:, None])
        remote_hit = miss & rhits.any(axis=-1)
        src_slot = jnp.argmax(rhits, axis=-1)
        src_cache = reqs.cluster * geom.cluster_size + src_slot
        prank, psize = group_rank(src_cache, remote_hit, geom.n_cores)
        xfer = geom.lat_xbar + prank.astype(jnp.float32) * geom.svc_port
        # every peer cache's tag port serves every probe in the cluster
        occupancy = jnp.where(
            miss, n_miss.astype(jnp.float32) * geom.svc_probe, 0.0)
        occupancy = jnp.maximum(
            occupancy,
            jnp.where(remote_hit,
                      psize.astype(jnp.float32) * geom.svc_port, 0.0))
        l1 = tagarray.touch(l1, reqs.core, set_idx, way, t, hit,
                            set_dirty=reqs.is_write)
        return L1Outcome(
            l1=l1,
            served=hit | remote_hit,
            l1_time=jnp.where(hit, geom.lat_l1 * 1.0,
                              TAG_CHECK + probe_wait
                              + jnp.where(remote_hit, xfer, 0.0)),
            go_l2=miss & ~remote_hit,
            pre_l2=TAG_CHECK + probe_wait,   # probes extend the L2 path
            occupancy=occupancy,
            fill_cache=reqs.core,
            fill_set=set_idx,
            local_hits=hit,
            remote_hits=remote_hit,
            noc_flits=(jnp.sum(miss) * (geom.cluster_size - 1)
                       + jnp.sum(remote_hit) * geom.flits_per_line),
            # Topology models see only the point-to-point *data*
            # transfers (line from the serving peer). The broadcast
            # probes are already priced inside this policy
            # (noc_delay/probe_wait above) and ride the dedicated probe
            # channels — routing them through the data network too
            # would double-charge them, and only on hits.
            noc_src=jnp.where(remote_hit, src_cache, reqs.core),
            noc_req_flits=remote_hit * (geom.flits_per_line * 1.0),
        )
