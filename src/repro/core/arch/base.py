"""Architecture-policy interface for the cache-hierarchy simulator.

The simulator is a pipeline of stages; only the first — the L1 complex —
differs between contention-mitigation architectures:

    L1 policy stage  ->  shared L2 stage  ->  L1 fill stage  ->  timing

An :class:`ArchPolicy` implements the L1 stage: given the per-round
request batch and the L1 tag state, it decides which requests are served
inside the L1 complex, at what latency, with what serial-resource
occupancy, and where misses fill on return. Everything downstream
(L2 queueing, DRAM, fill, warp-timing) is policy-independent and lives
in ``repro.core.simulator``.

New architectures subclass :class:`ArchPolicy`, implement ``l1_stage``,
and register themselves with :func:`repro.core.arch.register_arch` — no
core edits required.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Union

import jax.numpy as jnp

from repro.core import tagarray
from repro.core.geometry import GpuGeometry
from repro.core.tagarray import ReplacementPolicy

#: Cycles to detect an L1 miss (tag check before dispatching onwards).
TAG_CHECK = 8


class RequestBatch(NamedTuple):
    """One round's flattened requests plus derived routing indices.

    R = n_cores * m requests; G = cluster size.
    """
    addr: jnp.ndarray        # (R,) int32 line addresses
    is_write: jnp.ndarray    # (R,) bool
    core: jnp.ndarray        # (R,) int32 issuing core
    cluster: jnp.ndarray     # (R,) int32 cluster of the issuing core
    self_slot: jnp.ndarray   # (R,) int32 core's slot within its cluster
    set_idx: jnp.ndarray     # (R,) int32 local L1 set of addr
    bank: jnp.ndarray        # (R,) int32 local L1 bank of addr
    peers: jnp.ndarray       # (R, G) int32 cache ids of the whole cluster

    @property
    def n_requests(self) -> int:
        return self.addr.shape[0]


class L1Outcome(NamedTuple):
    """What the L1 complex did with the round's requests.

    Every field is (R,) unless noted. ``noc_flits`` is the scalar NoC
    traffic the policy itself generated (probes, peer transfers);
    downstream stages add L2/write-back traffic on top.
    """
    l1: tagarray.TagState           # post-probe/touch L1 tag state
    served: jnp.ndarray             # request completed inside L1 complex
    l1_time: jnp.ndarray            # float32 completion time if served
    go_l2: jnp.ndarray              # request continues to L2
    pre_l2: jnp.ndarray             # float32 cycles spent before L2 dispatch
    occupancy: jnp.ndarray          # float32 serial-resource busy time
    fill_cache: jnp.ndarray         # int32 tag array to fill on return
    fill_set: jnp.ndarray           # int32 set to fill on return
    local_hits: jnp.ndarray         # bool, for hit-rate accounting
    remote_hits: jnp.ndarray        # bool, served by a peer L1
    noc_flits: Union[jnp.ndarray, float]  # scalar flit count this round
    bypass_fill: Optional[jnp.ndarray] = None  # bool; True = skip L1 fill
    #: (R,) int32 core whose cache serves each request (the NoC source
    #: for remote transfers); None = the requesting core itself.
    noc_src: Optional[jnp.ndarray] = None
    #: (R,) float32 probe + data flits each request puts on the
    #: L1-complex interconnect (``repro.core.noc``); None = the default
    #: ``remote_hits * flits_per_line``. L2/write-back traffic rides
    #: the memory-side network and is *not* included here.
    noc_req_flits: Optional[jnp.ndarray] = None


@dataclasses.dataclass(frozen=True)
class ArchPolicy:
    """A pluggable L1-complex architecture.

    ``replacement`` selects the victim scheme the policy's tag probes and
    the shared fill stage use for this architecture's L1 arrays (the L2
    always runs LRU).

    ``victim_ways`` / ``track_thrash`` declare the policy's TagState
    extensions (victim tag buffer entries per cache, per-core thrash
    counters). The simulator sizes the L1 state by the *maximum* over a
    dataflow group, so a policy that declares an extension can stack
    with family members that ignore it: the extension arrays are
    zero-sized when nobody asks for them (existing goldens stay
    bit-exact) and dead weight in the branches that do not read them.
    """
    name: str
    replacement: ReplacementPolicy = ReplacementPolicy.LRU
    victim_ways: int = 0
    track_thrash: bool = False

    @property
    def stack_key(self) -> str:
        """Dataflow-group tag for sweep stacking.

        Architectures that return the same ``stack_key`` declare an
        identical dataflow shape (same tag-state layout, same output
        pytree per round), so ``repro.core.sweep`` may compile them into
        one vmapped executable and select the active policy per grid
        point with a traced index. The default — the policy's own name —
        opts out of cross-policy stacking; families of variants (e.g.
        the ATA replacement/bypass variants) override it to share.
        """
        return self.name

    def l1_stage(self, geom: GpuGeometry, l1: tagarray.TagState,
                 reqs: RequestBatch, t: jnp.ndarray, *,
                 backend: str = "lax") -> L1Outcome:
        """Run the policy's L1 complex over one round's requests.

        ``backend`` selects the probe lowering (``repro.core.probe``) —
        a *static* simulator axis threaded down from
        ``simulate(..., probe_backend=...)``. Only the ATA family has a
        probe chain to lower; policies without one accept and ignore
        the keyword (backend choice never changes any policy's results
        — tier-1 tested).
        """
        raise NotImplementedError
