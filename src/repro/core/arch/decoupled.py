"""Decoupled sharing: address-sliced home L1 caches [Ibrahim'20/'21].

Every request — hit or miss — is routed to the home cache its address
hashes to and pays that home's bank-port queue.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import tagarray
from repro.core.arch.base import TAG_CHECK, ArchPolicy, L1Outcome, RequestBatch
from repro.core.contention import group_rank
from repro.core.geometry import GpuGeometry


@dataclasses.dataclass(frozen=True)
class DecoupledPolicy(ArchPolicy):
    name: str = "decoupled"

    def l1_stage(self, geom: GpuGeometry, l1: tagarray.TagState,
                 reqs: RequestBatch, t, *,
                 backend: str = "lax") -> L1Outcome:
        del backend   # no probe chain to lower (ATA-family axis)
        R = reqs.n_requests
        addr = reqs.addr
        home = (reqs.cluster * geom.cluster_size
                + (addr % geom.cluster_size))
        home_set = ((addr // geom.cluster_size) % geom.l1_sets
                    ).astype(jnp.int32)
        home_bank = home_set % geom.l1_banks
        hit, way, _ = tagarray.probe(l1, home, home_set, addr,
                                     policy=self.replacement)
        # every request, hit or miss, pays the home bank-port queue; the
        # bank is a serial resource, so its busy time is also a
        # throughput (occupancy) bound warps cannot hide.
        key = home * geom.l1_banks + home_bank
        rank, size = group_rank(key, jnp.ones((R,), bool),
                                geom.n_cores * geom.l1_banks)
        delay = rank.astype(jnp.float32) * geom.svc_bank
        occupancy = size.astype(jnp.float32) * geom.svc_bank
        l1 = tagarray.touch(l1, home, home_set, way, t, hit,
                            set_dirty=reqs.is_write)
        return L1Outcome(
            l1=l1,
            served=hit,
            l1_time=jnp.where(hit,
                              geom.lat_l1 + geom.lat_home + delay,
                              TAG_CHECK + delay),
            go_l2=~hit,
            pre_l2=TAG_CHECK + delay,
            occupancy=occupancy,
            fill_cache=home,
            fill_set=home_set,
            local_hits=hit,
            remote_hits=jnp.zeros((R,), bool),
            noc_flits=jnp.sum(hit) * geom.flits_per_line,
            # home-cache hits ship the line from the home core's port;
            # a line whose home is the requesting core itself never
            # leaves the core and crosses nothing
            noc_src=home,
            noc_req_flits=((hit & (home != reqs.core))
                           * (geom.flits_per_line * 1.0)),
        )
