"""ATA-Cache core: the paper's contribution as a composable JAX library.

Public API:
  GpuGeometry, PAPER_GEOMETRY — simulated GPU (paper Table II)
  GeomStructure, GeomScalars, split_geometry — static/traced geometry split
  simulate, Trace, SimResult  — run one trace through one architecture
  simulate_batch, simulate_many — vmapped sweeps over stacked traces
  SweepGrid, SweepPoint, SweepReport — device-sharded multi-axis grids
  ARCHITECTURES               — ("private", "remote", "decoupled", "ata")
  ArchPolicy, register_arch, get_arch, registered_archs — policy plug-in
  NocModel, register_noc, get_noc, registered_nocs — interconnect plug-in
  PAPER_NOCS, NocStats        — topology comparison set + SimResult block
  ReplacementPolicy           — L1 victim selection (LRU / FIFO / RANDOM)
  APPS, make_trace            — calibrated workload suite (repro.core.trace)
  WorkloadMix                 — multi-tenant co-scheduling composer
  AppStats                    — per-app attribution block on SimResult
  run_app, run_suite, normalized_ipc — experiment drivers
  MixResult, run_mixes        — fairness metrics over co-scheduled mixes
  TelemetryConfig             — opt-in windowed observability (repro.obs)
"""
from repro.core.geometry import (GeomScalars, GeomStructure, GpuGeometry,
                                 PAPER_GEOMETRY, split_geometry)
from repro.core.simulator import (ARCHITECTURES, AppStats, NocStats,
                                  SimResult, Trace, simulate,
                                  simulate_batch, simulate_many, trace_kind)
from repro.core.sweep import SweepGrid, SweepPoint, SweepReport, SweepRun
from repro.core.arch import (ArchPolicy, L1Outcome, RequestBatch, get_arch,
                             register_arch, registered_archs)
from repro.core.noc import (NocModel, NocTraffic, NocTransit, PAPER_NOCS,
                            get_noc, register_noc, registered_nocs)
from repro.core.tagarray import ReplacementPolicy
from repro.core.telemetry import TelemetryConfig
from repro.core.trace import (APPS, HIGH_LOCALITY, LOW_LOCALITY, AppParams,
                              WorkloadMix, kernel_params, make_trace)
from repro.core.metrics import (AppResult, MixResult, MixRun, app_traces,
                                geomean, normalized_ipc, run_app, run_mixes,
                                run_suite)

__all__ = [
    "GpuGeometry", "PAPER_GEOMETRY", "GeomStructure", "GeomScalars",
    "split_geometry", "ARCHITECTURES", "SimResult", "AppStats", "Trace",
    "trace_kind", "simulate", "simulate_batch", "simulate_many", "SweepGrid",
    "SweepPoint", "SweepReport", "SweepRun", "ArchPolicy", "L1Outcome",
    "RequestBatch", "get_arch", "register_arch", "registered_archs",
    "NocModel", "NocTraffic", "NocTransit", "NocStats", "PAPER_NOCS",
    "get_noc", "register_noc", "registered_nocs",
    "ReplacementPolicy", "APPS", "HIGH_LOCALITY", "LOW_LOCALITY", "AppParams",
    "WorkloadMix", "kernel_params", "make_trace", "AppResult", "app_traces",
    "geomean", "normalized_ipc", "run_app", "run_suite", "MixResult",
    "MixRun", "run_mixes", "TelemetryConfig",
]
