"""ATA-Cache core: the paper's contribution as a composable JAX library.

Public API:
  GpuGeometry, PAPER_GEOMETRY — simulated GPU (paper Table II)
  simulate, Trace, SimResult  — run one trace through one architecture
  ARCHITECTURES               — ("private", "remote", "decoupled", "ata")
  APPS, make_trace            — calibrated workload suite
  run_app, run_suite, normalized_ipc — experiment drivers
"""
from repro.core.geometry import GpuGeometry, PAPER_GEOMETRY
from repro.core.simulator import ARCHITECTURES, SimResult, Trace, simulate
from repro.core.workloads import (APPS, HIGH_LOCALITY, LOW_LOCALITY,
                                  AppParams, make_trace)
from repro.core.metrics import (AppResult, geomean, normalized_ipc, run_app,
                                run_suite)

__all__ = [
    "GpuGeometry", "PAPER_GEOMETRY", "ARCHITECTURES", "SimResult", "Trace",
    "simulate", "APPS", "HIGH_LOCALITY", "LOW_LOCALITY", "AppParams",
    "make_trace", "AppResult", "geomean", "normalized_ipc", "run_app",
    "run_suite",
]
