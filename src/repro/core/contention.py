"""Vectorized contention primitives.

The paper's contention effects (decoupled-sharing bank conflicts, ATA
remote-port conflicts, remote-sharing probe queues, L2 partition queues)
are all instances of one primitive: requests arriving at a keyed resource
in the same round are served serially, so request *i* waits
``rank_i * svc`` cycles where ``rank_i`` is its position within its
conflict group.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def group_rank(keys: jnp.ndarray, mask: jnp.ndarray, n_keys: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rank of each masked request within its key group, and group size.

    keys : (R,) int32 in [0, n_keys); mask : (R,) bool.
    rank : (R,) int32 — #earlier masked requests with the same key (0 if
           unmasked); size : (R,) int32 — total masked requests in group.
    """
    onehot = (keys[:, None] == jnp.arange(n_keys)[None, :]) & mask[:, None]
    counts = onehot.sum(axis=0)                           # (K,)
    before = jnp.cumsum(onehot, axis=0) - onehot          # exclusive
    rank = jnp.take_along_axis(before, keys[:, None], axis=1)[:, 0]
    size = counts[keys]
    rank = jnp.where(mask, rank, 0)
    size = jnp.where(mask, size, 0)
    return rank.astype(jnp.int32), size.astype(jnp.int32)
