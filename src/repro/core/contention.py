"""Vectorized contention primitives.

The paper's contention effects (decoupled-sharing bank conflicts, ATA
remote-port conflicts, remote-sharing probe queues, L2 partition queues)
are all instances of one primitive: requests arriving at a keyed resource
in the same round are served serially, so request *i* waits
``rank_i * svc`` cycles where ``rank_i`` is its position within its
conflict group.

Two implementations coexist:

* :func:`_group_rank_onehot` — the original O(R*K) one-hot matrix
  formulation. Kept as the executable reference (a hypothesis test
  asserts equivalence) and as the fallback when a sort key would not
  fit in int32.
* the sort/segment-sum path (default) — O(R log R + R): one stable
  argsort on a composite (key, index) sort key, a cumulative sum over
  the sorted values, and a segment-base subtraction. The same machinery
  generalizes from ranks (unit weights) to weighted prefix sums
  (:func:`group_prefix_sum`), which the NoC models use for per-port
  flit arbitration.

Both paths return identical integers, so downstream float timing math —
and therefore every committed golden — is bit-exact across them.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_INT32_MAX = jnp.iinfo(jnp.int32).max


def _group_rank_onehot(keys: jnp.ndarray, mask: jnp.ndarray, n_keys: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference one-hot implementation (O(R*K) time and memory)."""
    onehot = (keys[:, None] == jnp.arange(n_keys)[None, :]) & mask[:, None]
    counts = onehot.sum(axis=0)                           # (K,)
    before = jnp.cumsum(onehot, axis=0) - onehot          # exclusive
    rank = jnp.take_along_axis(before, keys[:, None], axis=1)[:, 0]
    size = counts[keys]
    rank = jnp.where(mask, rank, 0)
    size = jnp.where(mask, size, 0)
    return rank.astype(jnp.int32), size.astype(jnp.int32)


def _sort_fits_int32(n_keys: int, n_requests: int) -> bool:
    """Whether the composite (key, index) sort key fits in int32."""
    return (n_keys + 1) * n_requests < _INT32_MAX


def _segment_prefix(keys: jnp.ndarray, values: jnp.ndarray
                    ) -> jnp.ndarray:
    """Exclusive prefix sum of ``values`` within equal-``keys`` segments.

    ``keys`` must already be sorted and ``values`` non-negative (the
    running cumulative sum is then non-decreasing, which lets the
    segment base be recovered with a ``cummax``). Dtype-generic:
    integer ranks accumulate in int32 (exact for any group size),
    float weights in float32.
    """
    csum = jnp.cumsum(values) - values               # exclusive, global
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), keys[1:] != keys[:-1]])
    base = jax.lax.cummax(jnp.where(is_new, csum, jnp.zeros_like(csum)))
    return csum - base


def _group_prefix_onehot(keys: jnp.ndarray, v: jnp.ndarray, n_keys: int
                         ) -> jnp.ndarray:
    """Reference one-hot exclusive prefix sum (O(R*K); ``v`` pre-masked)."""
    onehot = (keys[:, None] == jnp.arange(n_keys)[None, :]) * v[:, None]
    before = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(before, keys[:, None], axis=1)[:, 0]


def group_prefix_sum(keys: jnp.ndarray, values: jnp.ndarray,
                     mask: jnp.ndarray, n_keys: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-request exclusive prefix sum and total of ``values`` by key.

    keys   : (R,) int32 in [0, n_keys); values : (R,) float32 >= 0;
    mask   : (R,) bool.
    before : (R,) float32 — sum of earlier masked requests' values in
             the same key group (0 if unmasked);
    total  : (R,) float32 — group total (0 if unmasked).

    This is the weighted generalization of :func:`group_rank` (which is
    the unit-weight special case): the NoC crossbar model uses it for
    "flits ahead of mine at my injection port". Like ``group_rank`` it
    falls back to the one-hot reference when the composite sort key
    would overflow int32.

    Position within ``keys`` is arrival order, and the stable sort
    preserves it — which is what lets the serving engine's batched
    admission rounds reuse this primitive unchanged: the engine flattens
    a round's ``B x shards x blocks`` remote fetches *slot-major* into
    one NoC round, so earlier admission slots' flits rank ahead of
    later slots' at every port, the intra-round ordered accounting the
    batched round contract requires (see ``repro.serving.engine``).
    """
    R = keys.shape[0]
    v = jnp.where(mask, values, 0.0).astype(jnp.float32)
    totals = jnp.zeros((n_keys,), jnp.float32).at[keys].add(v)
    total = jnp.where(mask, totals[keys], 0.0)
    if R == 0:
        return v, total
    if not _sort_fits_int32(n_keys, R):
        return (jnp.where(mask, _group_prefix_onehot(keys, v, n_keys), 0.0),
                total)
    # Composite key: masked-out requests sort last, original order is
    # preserved inside a group (stable by construction — the index is
    # part of the key).
    k = jnp.where(mask, keys, n_keys)
    composite = k * jnp.int32(R) + jnp.arange(R, dtype=jnp.int32)
    order = jnp.argsort(composite)
    before_sorted = _segment_prefix(k[order], v[order])
    before = jnp.zeros_like(v).at[order].set(before_sorted)
    return jnp.where(mask, before, 0.0), total


def group_rank(keys: jnp.ndarray, mask: jnp.ndarray, n_keys: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rank of each masked request within its key group, and group size.

    keys : (R,) int32 in [0, n_keys); mask : (R,) bool.
    rank : (R,) int32 — #earlier masked requests with the same key (0 if
           unmasked); size : (R,) int32 — total masked requests in group.

    Hot path: sort/segment-sum, O(R log R + R) — the one-hot reference
    is O(R*K) and allocates an (R, K) matrix per call (K = e.g.
    n_cores * l1_banks inside every scanned round). Falls back to the
    reference when the composite sort key would overflow int32.
    """
    R = keys.shape[0]
    if R == 0 or not _sort_fits_int32(n_keys, R):
        return _group_rank_onehot(keys, mask, n_keys)
    m = mask.astype(jnp.int32)
    counts = jnp.zeros((n_keys,), jnp.int32).at[keys].add(m)
    size = jnp.where(mask, counts[keys], 0)
    k = jnp.where(mask, keys, n_keys)
    composite = k * jnp.int32(R) + jnp.arange(R, dtype=jnp.int32)
    order = jnp.argsort(composite)
    # int32 accumulation: exact for any group size (a float32 cumsum
    # would silently saturate ranks past 2**24)
    rank_sorted = _segment_prefix(k[order], m[order])
    rank = jnp.zeros((R,), jnp.int32).at[order].set(rank_sorted)
    rank = jnp.where(mask, rank, 0)
    return rank.astype(jnp.int32), size.astype(jnp.int32)
