"""Cache-hierarchy simulator: pluggable L1 policies over shared stages.

One ``lax.scan`` step models one *round*: every core issues ``m`` memory
requests (one coalesced load instruction). A round is a pipeline

    L1 policy stage  ->  shared L2 stage  ->  L1 fill stage  ->  timing

where only the first stage differs between architectures. The policies
live in ``repro.core.arch`` (one module each) and plug in through a
registry, so new contention-mitigation schemes need no edits here:

  private    : local L1 -> L2
  remote     : local L1 -> broadcast probes to cluster peers (NoC queue +
               probe service queue on the critical path) -> remote fetch
               or L2 *after* the probe round-trip  [Dublish'16, Ibrahim'19]
  decoupled  : address-sliced home cache; every request pays the home
               bank-port queue                       [Ibrahim'20/'21]
  ata        : aggregated tag array probed in parallel at zero added
               latency; only *known* remote hits cross the crossbar;
               writes are local-only with dirty-bit L2 diversion  [paper]
  ata_bypass : ata + CIAO-style interference-aware fill bypass
  ata_fifo   : ata under FIFO L1 replacement

Latency composition feeds a warp-level hiding model to produce IPC, and
the L1-complex portion of each request's latency reproduces Fig. 10.

Entry points: :func:`simulate` runs one trace; :func:`simulate_batch`
stacks same-shape traces and ``jax.vmap``s the scanned simulation over
the trace axis, so a whole sweep (all kernels of an app, a parameter
grid) costs one compilation instead of one ``jax.jit`` trace per kernel;
``repro.core.sweep.SweepGrid`` builds on the same core to batch the
*architecture* and *geometry* axes too and shard the stacked axis over
devices.

Geometry timing scalars are traced (``GeomScalars``), and a *group* of
same-dataflow architectures is compiled into one executable with the
active policy selected by a traced index (``lax.switch`` over the
per-round step), so an executable is keyed only by
(arch dataflow group, trace shape, geometry structure).
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tagarray
from repro.core.arch import (PAPER_ARCHITECTURES, ArchPolicy, get_arch,
                             registered_archs)
from repro.core.arch.base import TAG_CHECK, RequestBatch
from repro.core.contention import group_rank
from repro.core.geometry import (GEOM_SCALAR_FIELDS, GeomScalars,
                                 GeomStructure, GpuGeometry, PAPER_GEOMETRY,
                                 TracedGeometry, split_geometry)

#: Backwards-compatible alias: the paper's comparison set. The full,
#: extensible set is ``repro.core.arch.registered_archs()``.
ARCHITECTURES = PAPER_ARCHITECTURES


class Trace(NamedTuple):
    addr: np.ndarray       # (T, C, m) int32 line addresses
    is_write: np.ndarray   # (T, C, m) bool
    insn_per_req: float    # non-memory instructions amortized per request


class SimResult(NamedTuple):
    ipc: float
    l1_latency: float          # mean per-load L1-complex completion time
    local_hit_rate: float
    remote_hit_rate: float     # served by a peer L1 (0 for private/decoupled)
    l1_hit_rate: float         # served anywhere in the L1 complex
    l2_accesses: float
    dram_accesses: float
    noc_flits: float
    cycles: float
    instructions: float


def _l1_state(geom, policies: Sequence[ArchPolicy]) -> tagarray.TagState:
    """L1 tag state sized for a whole dataflow group.

    The zoo state extensions (victim buffer, thrash counters) take the
    *maximum* the group's policies declare, so stacked family members
    share one state pytree; policies that ignore an extension are
    bit-exact whether it is zero-sized or not.
    """
    victim = max(p.victim_ways for p in policies)
    thrash = geom.n_cores if any(p.track_thrash for p in policies) else 0
    return tagarray.init_tag_state(geom.n_cores, geom.l1_sets,
                                   geom.l1_ways, victim_ways=victim,
                                   thrash_lanes=thrash)


def _l2_state(geom) -> tagarray.TagState:
    return tagarray.init_tag_state(geom.l2_parts, geom.l2_sets, geom.l2_ways)


def _request_batch(geom, addr, is_write) -> RequestBatch:
    """Flatten one round's (C, m) requests and derive routing indices."""
    C, m = addr.shape
    R = C * m
    addr = addr.reshape(R)
    is_write = is_write.reshape(R)
    core = jnp.repeat(jnp.arange(C, dtype=jnp.int32), m)
    cluster = core // geom.cluster_size
    self_slot = core % geom.cluster_size
    set_idx = (addr % geom.l1_sets).astype(jnp.int32)
    bank = set_idx % geom.l1_banks
    peers = (cluster[:, None] * geom.cluster_size
             + jnp.arange(geom.cluster_size, dtype=jnp.int32)[None, :])
    return RequestBatch(addr=addr, is_write=is_write, core=core,
                        cluster=cluster, self_slot=self_slot,
                        set_idx=set_idx, bank=bank, peers=peers)


def _round(policy: ArchPolicy, geom, insn_per_req, state, xs):
    """One simulation round. state=(l1, l2, t, stats); xs=(addr, is_write).

    ``geom`` is a :class:`TracedGeometry` view (or a concrete
    ``GpuGeometry``): structure fields are static, timing scalars may be
    tracers.
    """
    l1, l2, t, stats = state
    addr, is_write = xs                      # (C, m)
    C, m = addr.shape
    reqs = _request_batch(geom, addr, is_write)
    addr = reqs.addr                         # (R,) flattened
    R = reqs.n_requests

    # ---- L1 policy stage (the only architecture-specific part) ------------
    out = policy.l1_stage(geom, l1, reqs, t)
    l1 = out.l1
    go_l2 = out.go_l2
    noc_flits = jnp.asarray(out.noc_flits, jnp.float32)
    occupancy = out.occupancy

    # ---- L2 stage ---------------------------------------------------------
    l2_part = (addr % geom.l2_parts).astype(jnp.int32)
    l2_set = ((addr // geom.l2_parts) % geom.l2_sets).astype(jnp.int32)
    l2_hit, l2_way, _ = tagarray.probe(l2, l2_part, l2_set, addr)
    l2_rank, l2_size = group_rank(l2_part, go_l2, geom.l2_parts)
    l2_time = (geom.lat_l2 + l2_rank.astype(jnp.float32) * geom.svc_l2
               + jnp.where(l2_hit, 0.0, geom.lat_dram * 1.0))
    occupancy = jnp.maximum(
        occupancy,
        jnp.where(go_l2, l2_size.astype(jnp.float32) * geom.svc_l2, 0.0))
    l2 = tagarray.touch(l2, l2_part, l2_set, l2_way, t, go_l2 & l2_hit)
    l2, _ = tagarray.fill(l2, l2_part, l2_set, l2_way, addr, t,
                          go_l2 & ~l2_hit)
    noc_flits = noc_flits + jnp.sum(go_l2) * geom.flits_per_line

    # ---- L1 fill on L2 return (and on remote fetch: replicate locally) ----
    fill_mask = go_l2 | out.remote_hits
    if out.bypass_fill is not None:
        fill_mask = fill_mask & ~out.bypass_fill
    _, fway, _ = tagarray.probe(l1, out.fill_cache, out.fill_set, addr,
                                policy=policy.replacement)
    l1, wb = tagarray.fill(l1, out.fill_cache, out.fill_set, fway, addr, t,
                           fill_mask, dirty=reqs.is_write)
    noc_flits = noc_flits + jnp.sum(wb) * geom.flits_per_line

    # ---- timing ------------------------------------------------------------
    latency = jnp.where(out.served, out.l1_time, out.pre_l2 + l2_time)  # (R,)
    # Warp multithreading hides individual request latencies; the core's
    # sustained pace is set by *mean* outstanding latency per load, while
    # serial-resource occupancy is a hard throughput bound (max over m).
    per_core_lat = latency.reshape(C, m).mean(axis=1)
    per_core_occ = occupancy.reshape(C, m).max(axis=1)
    pace = m * insn_per_req / geom.issue_rate
    round_cost = jnp.maximum(jnp.maximum(pace, per_core_occ),
                             per_core_lat / geom.hide)         # (C,)

    # Fig.10 metric: completion time of the L1 accesses of one load
    # instruction, over loads fully served by the L1 complex.
    all_served = out.served.reshape(C, m).all(axis=1)
    l1_complete = out.l1_time.reshape(C, m).max(axis=1)

    stats = {
        "cycles": stats["cycles"] + round_cost,
        "l1_lat_sum": stats["l1_lat_sum"]
        + jnp.sum(jnp.where(all_served, l1_complete, 0.0)),
        "l1_lat_n": stats["l1_lat_n"] + jnp.sum(all_served),
        "local_hits": stats["local_hits"] + jnp.sum(out.local_hits),
        "remote_hits": stats["remote_hits"] + jnp.sum(out.remote_hits),
        "requests": stats["requests"] + R,
        "l2_accesses": stats["l2_accesses"] + jnp.sum(go_l2),
        "dram": stats["dram"] + jnp.sum(go_l2 & ~l2_hit),
        "noc_flits": stats["noc_flits"] + noc_flits,
    }
    return (l1, l2, t + 1, stats), None


def _init_stats(geom) -> Dict[str, jnp.ndarray]:
    z = jnp.float32(0.0)
    return {"cycles": jnp.zeros((geom.n_cores,), jnp.float32),
            "l1_lat_sum": z, "l1_lat_n": z, "local_hits": z,
            "remote_hits": z, "requests": z, "l2_accesses": z,
            "dram": z, "noc_flits": z}


def _sim_core(archs: Tuple[str, ...], point_arrays,
              structure: GeomStructure):
    """Scan one grid point through the round pipeline.

    ``archs`` is a *dataflow group*: one or more same-dataflow
    architectures compiled together, the active one selected per point
    by the traced ``policy_idx`` (``lax.switch`` over the round step).
    ``point_arrays = (addr, is_write, insn_per_req, scalars,
    policy_idx)`` — everything but ``archs``/``structure`` is traced, so
    one executable serves whole (policy, timing-geometry, trace) grids.
    """
    addr, is_write, insn_per_req, scalars, policy_idx = point_arrays
    geom = TracedGeometry(structure, scalars)
    policies = [get_arch(a) for a in archs]
    state = (_l1_state(geom, policies), _l2_state(geom), jnp.int32(0),
             _init_stats(geom))
    steps = [functools.partial(_round, p, geom, insn_per_req)
             for p in policies]
    if len(steps) == 1:
        step = steps[0]
    else:
        def step(carry, xs):
            return jax.lax.switch(policy_idx, steps, carry, xs)
    (l1, l2, t, stats), _ = jax.lax.scan(step, state, (addr, is_write))
    return stats


#: One compilation per (arch group, trace shape, geometry structure).
_simulate = jax.jit(_sim_core, static_argnums=(0, 2))

#: Batched form: vmap over a leading grid-point axis, still one
#: compilation. ``repro.core.sweep`` adds device sharding on top.
_simulate_batch = jax.jit(
    lambda archs, point_arrays, structure: jax.vmap(
        lambda pa: _sim_core(archs, pa, structure))(point_arrays),
    static_argnums=(0, 2))


def _point_arrays(trace_like, scalars, policy_idx=0):
    """Pack one grid point's traced leaves for :func:`_sim_core`."""
    addr, is_write, insn = trace_like
    return (addr, is_write, insn, scalars, jnp.int32(policy_idx))


def round_signature(group: Tuple[str, ...], arch: str,
                    structure: GeomStructure,
                    round_shape: Tuple[int, int]):
    """Abstract shape/dtype pytree of one scanned round of ``arch``.

    The round is evaluated (``jax.eval_shape`` — no compilation, no
    FLOPs) with the L1 state sized for the whole dataflow ``group``,
    exactly as :func:`_sim_core` would compile it. Policies that may
    stack into one executable must produce identical signatures — the
    carried state pytrees are what ``lax.switch`` requires to line up —
    and ``repro.core.sweep.SweepGrid`` validates that with this
    function before it buckets a grid.
    """
    C, m = round_shape
    policies = [get_arch(a) for a in group]
    scalars = GeomScalars(*(jax.ShapeDtypeStruct((), jnp.float32)
                            for _ in GEOM_SCALAR_FIELDS))

    def one_round(scalars, addr, is_write):
        geom = TracedGeometry(structure, scalars)
        state = (_l1_state(geom, policies), _l2_state(geom), jnp.int32(0),
                 _init_stats(geom))
        new_state, _ = _round(get_arch(arch), geom, jnp.float32(1.0),
                              state, (addr, is_write))
        return new_state

    out = jax.eval_shape(one_round, scalars,
                         jax.ShapeDtypeStruct((C, m), jnp.int32),
                         jax.ShapeDtypeStruct((C, m), jnp.bool_))
    leaves, treedef = jax.tree.flatten(out)
    return treedef, tuple((l.shape, str(l.dtype)) for l in leaves)


def _summarize(stats, shape, insn_per_req: float) -> SimResult:
    T, C, m = shape
    instructions = T * C * m * insn_per_req
    cycles = float(stats["cycles"].max())
    requests = float(stats["requests"])
    local = float(stats["local_hits"])
    remote = float(stats["remote_hits"])
    lat_n = float(stats["l1_lat_n"])
    return SimResult(
        ipc=instructions / cycles,
        # NaN when no load was ever fully served inside the L1 complex
        # (possible on very short or all-streaming traces)
        l1_latency=(float(stats["l1_lat_sum"]) / lat_n if lat_n
                    else float("nan")),
        local_hit_rate=local / requests,
        remote_hit_rate=remote / requests,
        l1_hit_rate=(local + remote) / requests,
        l2_accesses=float(stats["l2_accesses"]),
        dram_accesses=float(stats["dram"]),
        noc_flits=float(stats["noc_flits"]),
        cycles=cycles,
        instructions=instructions,
    )


def _check_arch(arch: str) -> None:
    if arch not in registered_archs():
        raise ValueError(f"arch must be one of {registered_archs()}")


def simulate(arch: str, trace: Trace,
             geom: GpuGeometry = PAPER_GEOMETRY) -> SimResult:
    """Run a trace through one architecture and summarize."""
    _check_arch(arch)
    structure, scalars = split_geometry(geom)
    addr = jnp.asarray(trace.addr, jnp.int32)
    is_write = jnp.asarray(trace.is_write, bool)
    insn = jnp.float32(trace.insn_per_req)
    stats = jax.device_get(_simulate(
        (arch,), _point_arrays((addr, is_write, insn), scalars), structure))
    return _summarize(stats, trace.addr.shape, trace.insn_per_req)


def simulate_batch(arch: str, traces: Sequence[Trace],
                   geom: GpuGeometry = PAPER_GEOMETRY) -> List[SimResult]:
    """Run many same-shape traces through one architecture in one call.

    The traces are stacked on a new leading axis and the scanned
    simulation is ``jax.vmap``-ed over it, so the whole sweep is a single
    compiled executable (and a single device dispatch) regardless of how
    many traces are in the batch. All traces must share one (T, C, m)
    shape; :func:`simulate_many` handles mixed shapes by grouping.
    """
    _check_arch(arch)
    if not traces:
        return []
    shapes = {t.addr.shape for t in traces}
    if len(shapes) != 1:
        raise ValueError(
            f"simulate_batch needs same-shape traces, got {sorted(shapes)}; "
            "use simulate_many for mixed shapes")
    structure, scalars = split_geometry(geom)
    B = len(traces)
    addr = jnp.asarray(np.stack([t.addr for t in traces]), jnp.int32)
    is_write = jnp.asarray(np.stack([t.is_write for t in traces]), bool)
    insn = jnp.asarray([t.insn_per_req for t in traces], jnp.float32)
    batched = ((addr, is_write, insn,
                jax.tree.map(lambda s: jnp.broadcast_to(s, (B,)), scalars),
                jnp.zeros((B,), jnp.int32)))
    stats = jax.device_get(_simulate_batch((arch,), batched, structure))
    shape = next(iter(shapes))
    return [_summarize(jax.tree.map(lambda a: a[b], stats), shape,
                       traces[b].insn_per_req)
            for b in range(len(traces))]


def simulate_many(arch: str, traces: Sequence[Trace],
                  geom: GpuGeometry = PAPER_GEOMETRY) -> List[SimResult]:
    """``simulate_batch`` over arbitrary traces: group by shape, preserve
    input order."""
    _check_arch(arch)
    groups: Dict[tuple, List[int]] = {}
    for i, t in enumerate(traces):
        groups.setdefault(t.addr.shape, []).append(i)
    out: List[SimResult] = [None] * len(traces)  # type: ignore[list-item]
    for idxs in groups.values():
        for i, r in zip(idxs, simulate_batch(
                arch, [traces[i] for i in idxs], geom)):
            out[i] = r
    return out
