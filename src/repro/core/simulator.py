"""Cache-hierarchy simulator: pluggable L1 policies over shared stages.

One ``lax.scan`` step models one *round*: every core issues ``m`` memory
requests (one coalesced load instruction). A round is a pipeline

    L1 policy stage -> shared L2 stage -> L1 fill stage -> NoC stage
                                                        -> timing

where only the first stage differs between architectures, and the NoC
stage routes the round's remote-probe/remote-data flits through a
pluggable interconnect model (``repro.core.noc``: ``ideal`` — the
default, bit-exact with the pre-NoC simulator — ``crossbar`` with
carried per-port queue backpressure, ``ring`` with hop-distance
latency; per-link occupancy/delay accumulate in the scan carry and
surface as ``SimResult.noc``). The policies live in ``repro.core.arch``
(one module each) and plug in through a registry, so new
contention-mitigation schemes need no edits here:

  private    : local L1 -> L2
  remote     : local L1 -> broadcast probes to cluster peers (NoC queue +
               probe service queue on the critical path) -> remote fetch
               or L2 *after* the probe round-trip  [Dublish'16, Ibrahim'19]
  decoupled  : address-sliced home cache; every request pays the home
               bank-port queue                       [Ibrahim'20/'21]
  ata        : aggregated tag array probed in parallel at zero added
               latency; only *known* remote hits cross the crossbar;
               writes are local-only with dirty-bit L2 diversion  [paper]
  ata_bypass : ata + CIAO-style interference-aware fill bypass
  ata_fifo   : ata under FIFO L1 replacement

Latency composition feeds a warp-level hiding model to produce IPC, and
the L1-complex portion of each request's latency reproduces Fig. 10.

Entry points: :func:`simulate` runs one trace; :func:`simulate_batch`
stacks same-shape traces and ``jax.vmap``s the scanned simulation over
the trace axis, so a whole sweep (all kernels of an app, a parameter
grid) costs one compilation instead of one ``jax.jit`` trace per kernel;
``repro.core.sweep.SweepGrid`` builds on the same core to batch the
*architecture* and *geometry* axes too and shard the stacked axis over
devices.

Multi-tenant traces (``repro.core.trace.mix.WorkloadMix``) carry a
``core_app`` app-id channel and a per-core instruction-intensity
vector; the round accumulates hit/timing counters per app id inside
the scan carry and :func:`_summarize` folds them into
``SimResult.per_app`` (:class:`AppStats`). The app count is the only
new static dimension (:func:`trace_kind`), so same-shape mixes share
executables and solo traces keep exactly their pre-mix ones.

Geometry timing scalars are traced (``GeomScalars``), and a *group* of
same-dataflow architectures is compiled into one executable with the
active policy selected by a traced index (``lax.switch`` over the
per-round step), so an executable is keyed only by
(arch dataflow group, trace shape, geometry structure).
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tagarray
from repro.core.arch import (PAPER_ARCHITECTURES, ArchPolicy, get_arch,
                             registered_archs)
from repro.core.arch.base import TAG_CHECK, RequestBatch
from repro.core.contention import group_rank
from repro.core.geometry import (GEOM_SCALAR_FIELDS, GeomScalars,
                                 GeomStructure, GpuGeometry, PAPER_GEOMETRY,
                                 TracedGeometry, split_geometry)
from repro.core.noc import (NocModel, NocTraffic, get_noc, init_noc_state,
                            registered_nocs)
from repro.core.probe import (PROBE_BACKENDS,
                              check_probe_backend as _check_probe_backend)
from repro.core.telemetry import TelemetryConfig, log2_bucket

#: Backwards-compatible alias: the paper's comparison set. The full,
#: extensible set is ``repro.core.arch.registered_archs()``.
ARCHITECTURES = PAPER_ARCHITECTURES


class _TraceBase(NamedTuple):
    addr: np.ndarray       # (T, C, m) int32 line addresses
    is_write: np.ndarray   # (T, C, m) bool
    #: non-memory instructions amortized per request — a scalar, or a
    #: (C,) float32 vector for multi-app mixes (per-core intensity)
    insn_per_req: Union[float, np.ndarray]
    #: (C,) int32 app id per core (multi-tenant mixes), or None — the
    #: canonical single-app trace (all cores app 0)
    core_app: Optional[np.ndarray] = None


class Trace(_TraceBase):
    """A request trace with strict dtype validation at the boundary.

    The simulator treats ``addr``/``is_write`` dtypes and the
    ``insn_per_req``/``core_app`` *shapes* as part of the executable
    key, so a hand-built trace that silently promoted ``addr`` to int64
    or ``is_write`` to int8 would either fail deep inside jit or double
    the compiled-executable count. Validation therefore happens here —
    at construction — not only inside ``make_trace``:

    * ``addr`` must already be int32 (use
      ``repro.core.trace.generators._require_int32`` to narrow safely);
    * ``is_write`` must be bool and shape-match ``addr``;
    * ``insn_per_req`` may be a python scalar or a (C,) vector; a
      uniform vector collapses to its scalar so single-app traces keep
      their executable regardless of how they were built;
    * ``core_app`` ids must be dense (every id in ``0..n_apps-1``
      assigned to at least one core); a single-app assignment collapses
      to ``None``, the canonical solo form.
    """
    __slots__ = ()

    def __new__(cls, addr, is_write, insn_per_req, core_app=None):
        addr = np.asarray(addr)
        if addr.dtype != np.int32:
            raise ValueError(
                f"Trace.addr must be int32, got {addr.dtype}; narrow "
                "explicitly (repro.core.trace.generators._require_int32 "
                "checks for overflow)")
        if addr.ndim != 3:
            raise ValueError(
                f"Trace.addr must be (rounds, cores, m), got {addr.shape}")
        is_write = np.asarray(is_write)
        if is_write.dtype != np.bool_:
            raise ValueError(
                f"Trace.is_write must be bool, got {is_write.dtype}")
        if is_write.shape != addr.shape:
            raise ValueError(
                f"Trace.is_write shape {is_write.shape} != addr shape "
                f"{addr.shape}")
        C = addr.shape[1]
        if np.ndim(insn_per_req) == 0:
            insn_per_req = float(insn_per_req)
        else:
            v = np.asarray(insn_per_req, np.float32)
            if v.shape != (C,):
                raise ValueError(
                    f"Trace.insn_per_req must be a scalar or ({C},) "
                    f"per-core vector, got shape {v.shape}")
            if np.all(v == v[0]):
                insn_per_req = float(v[0])   # canonical scalar form
            else:
                insn_per_req = v
        if core_app is not None:
            ca = np.asarray(core_app)
            if not np.issubdtype(ca.dtype, np.integer):
                raise ValueError(
                    f"Trace.core_app must be integer app ids, got "
                    f"{ca.dtype}")
            if ca.shape != (C,):
                raise ValueError(
                    f"Trace.core_app must be ({C},) — one app id per "
                    f"core — got shape {ca.shape}")
            ids = np.unique(ca)
            if ids[0] != 0 or ids[-1] != ids.size - 1:
                raise ValueError(
                    "Trace.core_app ids must be dense 0..n_apps-1 "
                    f"(every app owns at least one core), got {ids.tolist()}")
            core_app = None if ids.size == 1 else ca.astype(np.int32)
        return super().__new__(cls, addr, is_write, insn_per_req, core_app)

    def _replace(self, **kwds) -> "Trace":
        """Route through ``__new__`` so replaced traces re-validate.

        The inherited ``NamedTuple._replace`` builds via
        ``tuple.__new__`` and would silently skip the strict boundary
        checks (an int64 ``addr`` smuggled in this way would later be
        wrapped by ``jnp.asarray(..., int32)`` — exactly the corruption
        the validation exists to prevent).
        """
        fields = self._asdict()
        fields.update(kwds)
        return Trace(**fields)

    @property
    def n_cores(self) -> int:
        return self.addr.shape[1]

    @property
    def n_apps(self) -> int:
        """Number of co-scheduled apps (1 for the canonical solo form)."""
        return 1 if self.core_app is None else int(self.core_app.max()) + 1

    @property
    def core_app_ids(self) -> np.ndarray:
        """(C,) int32 app id per core; zeros for the solo form."""
        if self.core_app is None:
            return np.zeros((self.n_cores,), np.int32)
        return self.core_app

    @property
    def insn_vector(self) -> np.ndarray:
        """(C,) float64 per-core instruction intensity."""
        if np.ndim(self.insn_per_req) == 0:
            return np.full((self.n_cores,), float(self.insn_per_req))
        return np.asarray(self.insn_per_req, np.float64)


class AppStats(NamedTuple):
    """Per-app attribution slice of one simulation (raw counters).

    Raw sums only — never NaN — so nested tuple equality between the
    grid and per-point paths stays exact; ratios are derived
    properties (``l1_latency`` is NaN when no load of this app was ever
    fully served inside the L1 complex, mirroring ``SimResult``).
    """
    app: int            # dense app id (mix slot)
    cores: int          # cores assigned to this app
    instructions: float
    cycles: float       # completion time: max over the app's cores
    requests: float
    local_hits: float
    remote_hits: float
    l1_lat_sum: float
    l1_lat_n: float

    @property
    def ipc(self) -> float:
        return self.instructions / self.cycles

    @property
    def local_hit_rate(self) -> float:
        return self.local_hits / self.requests

    @property
    def remote_hit_rate(self) -> float:
        return self.remote_hits / self.requests

    @property
    def l1_hit_rate(self) -> float:
        return (self.local_hits + self.remote_hits) / self.requests

    @property
    def l1_latency(self) -> float:
        return self.l1_lat_sum / self.l1_lat_n if self.l1_lat_n \
            else float("nan")


class NocStats(NamedTuple):
    """Interconnect block of one simulation (``repro.core.noc``).

    Conservation counters are at injection granularity —
    ``flits_injected == flits_delivered + flits_queued`` holds after
    every round and at end-of-sim for every registered model (tier-1
    tested), up to float32 accumulation error when the per-port drain
    rate is not exactly representable (e.g. ``noc_bw/cluster_size =
    0.2``): backpressure may *defer* flits, never lose them.
    Utilizations normalize per-link busy cycles by the run's
    completion time; ``max_link_util`` is the hotspot link. The flit
    counters track traffic under every model (``ideal`` delivers
    everything instantly: ``injected == delivered``, ``queued == 0``);
    the *queueing and utilization* fields are 0.0 under ``ideal`` (no
    links, no delay), so solo and grid-stacked runs agree exactly
    regardless of how large a stacked sibling sized the carried link
    arrays.
    """
    flits_injected: float
    flits_delivered: float
    flits_queued: float        # still in a port queue at end-of-sim
    mean_queue_delay: float    # mean NoC delay over crossing requests
    max_link_util: float       # hotspot: busiest link busy / cycles
    mean_link_util: float      # mean busy / cycles over *active* links

    @property
    def conserved(self) -> bool:
        drift = abs(self.flits_injected
                    - (self.flits_delivered + self.flits_queued))
        return drift <= max(1e-6 * self.flits_injected, 1e-3)


_ZERO_NOC = NocStats(0.0, 0.0, 0.0, 0.0, 0.0, 0.0)


class SimResult(NamedTuple):
    ipc: float
    l1_latency: float          # mean per-load L1-complex completion time
    local_hit_rate: float
    remote_hit_rate: float     # served by a peer L1 (0 for private/decoupled)
    l1_hit_rate: float         # served anywhere in the L1 complex
    l2_accesses: float
    dram_accesses: float
    noc_flits: float
    cycles: float
    instructions: float
    #: per-app attribution (one AppStats per mix slot; a single entry
    #: covering every core for solo traces)
    per_app: Tuple[AppStats, ...] = ()
    #: interconnect metrics (all-zero under the default ``ideal`` model)
    noc: NocStats = _ZERO_NOC


def _l1_state(geom, policies: Sequence[ArchPolicy]) -> tagarray.TagState:
    """L1 tag state sized for a whole dataflow group.

    The zoo state extensions (victim buffer, thrash counters) take the
    *maximum* the group's policies declare, so stacked family members
    share one state pytree; policies that ignore an extension are
    bit-exact whether it is zero-sized or not.
    """
    victim = max(p.victim_ways for p in policies)
    thrash = geom.n_cores if any(p.track_thrash for p in policies) else 0
    return tagarray.init_tag_state(geom.n_cores, geom.l1_sets,
                                   geom.l1_ways, victim_ways=victim,
                                   thrash_lanes=thrash)


def _l2_state(geom) -> tagarray.TagState:
    return tagarray.init_tag_state(geom.l2_parts, geom.l2_sets, geom.l2_ways)


def _noc_state(geom, models: Sequence[NocModel]):
    """Carried NoC state sized for a whole stacked model group.

    Mirrors :func:`_l1_state`: the link/queue arrays take the *maximum*
    ``n_links`` the group's models declare, so stacked members share
    one state pytree; a model that ignores the arrays (``ideal``) is
    bit-exact whether they are zero-sized or not.
    """
    return init_noc_state(max(m.n_links(geom) for m in models))


def _request_batch(geom, addr, is_write) -> RequestBatch:
    """Flatten one round's (C, m) requests and derive routing indices."""
    C, m = addr.shape
    R = C * m
    addr = addr.reshape(R)
    is_write = is_write.reshape(R)
    core = jnp.repeat(jnp.arange(C, dtype=jnp.int32), m)
    cluster = core // geom.cluster_size
    self_slot = core % geom.cluster_size
    set_idx = (addr % geom.l1_sets).astype(jnp.int32)
    bank = set_idx % geom.l1_banks
    peers = (cluster[:, None] * geom.cluster_size
             + jnp.arange(geom.cluster_size, dtype=jnp.int32)[None, :])
    return RequestBatch(addr=addr, is_write=is_write, core=core,
                        cluster=cluster, self_slot=self_slot,
                        set_idx=set_idx, bank=bank, peers=peers)


def _round(policy: ArchPolicy, nocs: Sequence[NocModel], noc_idx,
           geom, insn_per_req, core_app, state, xs, *,
           probe_backend: str = "lax",
           telemetry: Optional[TelemetryConfig] = None):
    """One simulation round. state=(l1, l2, noc, t, stats);
    xs=(addr, is_write).

    ``geom`` is a :class:`TracedGeometry` view (or a concrete
    ``GpuGeometry``): structure fields are static, timing scalars may be
    tracers. ``insn_per_req`` is a scalar or (C,) vector; ``core_app``
    is the (C,) int32 app-id channel feeding the per-app attribution
    scatter-adds (all zeros for solo traces). ``nocs`` is the stacked
    interconnect-model group compiled into this executable; the traced
    ``noc_idx`` selects the active one (``lax.switch`` when the group
    has more than one member). ``probe_backend`` selects the L1 probe
    lowering (``repro.core.probe``) — *static*, since the backends
    lower structurally different programs; every backend is bit-exact.
    """
    l1, l2, noc, t, stats = state
    addr, is_write = xs                      # (C, m)
    C, m = addr.shape
    reqs = _request_batch(geom, addr, is_write)
    addr = reqs.addr                         # (R,) flattened
    R = reqs.n_requests

    # ---- L1 policy stage (the only architecture-specific part) ------------
    out = policy.l1_stage(geom, l1, reqs, t, backend=probe_backend)
    l1 = out.l1
    go_l2 = out.go_l2
    noc_flits = jnp.asarray(out.noc_flits, jnp.float32)
    occupancy = out.occupancy

    # ---- L2 stage ---------------------------------------------------------
    l2_part = (addr % geom.l2_parts).astype(jnp.int32)
    l2_set = ((addr // geom.l2_parts) % geom.l2_sets).astype(jnp.int32)
    l2_hit, l2_way, _ = tagarray.probe(l2, l2_part, l2_set, addr)
    l2_rank, l2_size = group_rank(l2_part, go_l2, geom.l2_parts)
    l2_time = (geom.lat_l2 + l2_rank.astype(jnp.float32) * geom.svc_l2
               + jnp.where(l2_hit, 0.0, geom.lat_dram * 1.0))
    occupancy = jnp.maximum(
        occupancy,
        jnp.where(go_l2, l2_size.astype(jnp.float32) * geom.svc_l2, 0.0))
    l2 = tagarray.touch(l2, l2_part, l2_set, l2_way, t, go_l2 & l2_hit)
    l2, _ = tagarray.fill(l2, l2_part, l2_set, l2_way, addr, t,
                          go_l2 & ~l2_hit)
    noc_flits = noc_flits + jnp.sum(go_l2) * geom.flits_per_line

    # ---- L1 fill on L2 return (and on remote fetch: replicate locally) ----
    fill_mask = go_l2 | out.remote_hits
    if out.bypass_fill is not None:
        fill_mask = fill_mask & ~out.bypass_fill
    _, fway, _ = tagarray.probe(l1, out.fill_cache, out.fill_set, addr,
                                policy=policy.replacement)
    l1, wb = tagarray.fill(l1, out.fill_cache, out.fill_set, fway, addr, t,
                           fill_mask, dirty=reqs.is_write)
    noc_flits = noc_flits + jnp.sum(wb) * geom.flits_per_line

    # ---- NoC stage: remote-probe/remote-data flits through the active
    # interconnect model (repro.core.noc). The policies' own memoryless
    # per-round contention stays put; the model adds topology effects —
    # cross-round queue backpressure, hop latency, link hotspots — and
    # the `ideal` model adds exactly zero (bit-exact with the pre-NoC
    # simulator).
    req_flits = out.noc_req_flits
    if req_flits is None:
        req_flits = out.remote_hits * (geom.flits_per_line * 1.0)
    req_flits = jnp.asarray(req_flits, jnp.float32)
    traffic = NocTraffic(
        src=out.noc_src if out.noc_src is not None else reqs.core,
        dst=reqs.core, cluster=reqs.cluster, flits=req_flits,
        mask=req_flits > 0)
    if len(nocs) == 1:
        transit = nocs[0].transit(geom, noc, traffic)
    else:
        transit = jax.lax.switch(
            noc_idx, [functools.partial(m.transit, geom) for m in nocs],
            noc, traffic)
    noc = transit.state
    occupancy = jnp.maximum(occupancy, transit.occupancy)

    # ---- timing ------------------------------------------------------------
    latency = (jnp.where(out.served, out.l1_time, out.pre_l2 + l2_time)
               + transit.delay)                                     # (R,)
    # Warp multithreading hides individual request latencies; the core's
    # sustained pace is set by *mean* outstanding latency per load, while
    # serial-resource occupancy is a hard throughput bound (max over m).
    per_core_lat = latency.reshape(C, m).mean(axis=1)
    per_core_occ = occupancy.reshape(C, m).max(axis=1)
    pace = m * insn_per_req / geom.issue_rate
    round_cost = jnp.maximum(jnp.maximum(pace, per_core_occ),
                             per_core_lat / geom.hide)         # (C,)

    # Fig.10 metric: completion time of the L1 accesses of one load
    # instruction, over loads fully served by the L1 complex. The NoC
    # transit delay of a remote hit is part of that completion time
    # (exactly 0.0 under `ideal`, so the golden pins are unaffected).
    all_served = out.served.reshape(C, m).all(axis=1)
    l1_complete = (out.l1_time + transit.delay).reshape(C, m).max(axis=1)

    # Per-app attribution: hit counters scatter-add by the issuing
    # core's app id inside the existing carry (hit counts are small
    # integers in float32 — exact regardless of accumulation order).
    req_app = core_app[reqs.core]                               # (R,)
    f32 = jnp.float32
    app_served_lat = jnp.where(all_served, l1_complete, 0.0)    # (C,)

    stats = {
        "cycles": stats["cycles"] + round_cost,
        "l1_lat_sum": stats["l1_lat_sum"] + jnp.sum(app_served_lat),
        "l1_lat_n": stats["l1_lat_n"] + jnp.sum(all_served),
        "local_hits": stats["local_hits"] + jnp.sum(out.local_hits),
        "remote_hits": stats["remote_hits"] + jnp.sum(out.remote_hits),
        "requests": stats["requests"] + R,
        "l2_accesses": stats["l2_accesses"] + jnp.sum(go_l2),
        "dram": stats["dram"] + jnp.sum(go_l2 & ~l2_hit),
        "noc_flits": stats["noc_flits"] + noc_flits,
        "app_local": stats["app_local"]
        .at[req_app].add(out.local_hits.astype(f32)),
        "app_remote": stats["app_remote"]
        .at[req_app].add(out.remote_hits.astype(f32)),
        "app_lat_sum": stats["app_lat_sum"]
        .at[core_app].add(app_served_lat),
        "app_lat_n": stats["app_lat_n"]
        .at[core_app].add(all_served.astype(f32)),
    }
    if telemetry is not None and telemetry.histograms:
        # log2-bucketed L1-complete latency histogram over served
        # loads (unserved cores contribute an add of 0 — a no-op).
        bucket = log2_bucket(l1_complete, telemetry.sim_hist_bins)
        stats["lat_hist"] = state[4]["lat_hist"] \
            .at[bucket].add(all_served.astype(jnp.int32))
    return (l1, l2, noc, t + 1, stats), None


def _init_stats(geom, n_apps: int = 1,
                telemetry: Optional[TelemetryConfig] = None
                ) -> Dict[str, jnp.ndarray]:
    z = jnp.float32(0.0)
    app = jnp.zeros((n_apps,), jnp.float32)
    stats = {"cycles": jnp.zeros((geom.n_cores,), jnp.float32),
             "l1_lat_sum": z, "l1_lat_n": z, "local_hits": z,
             "remote_hits": z, "requests": z, "l2_accesses": z,
             "dram": z, "noc_flits": z,
             "app_local": app, "app_remote": app,
             "app_lat_sum": app, "app_lat_n": app}
    if telemetry is not None and telemetry.histograms:
        stats["lat_hist"] = jnp.zeros((telemetry.sim_hist_bins,),
                                      jnp.int32)
    return stats


def _sim_core(archs: Tuple[str, ...], nocs: Tuple[str, ...], point_arrays,
              structure: GeomStructure, n_apps: int = 1,
              probe_backend: str = "lax",
              telemetry: Optional[TelemetryConfig] = None):
    """Scan one grid point through the round pipeline.

    ``archs`` is a *dataflow group*: one or more same-dataflow
    architectures compiled together, the active one selected per point
    by the traced ``policy_idx`` (``lax.switch`` over the round step);
    ``nocs`` is the stacked interconnect-model group, selected by the
    traced ``noc_idx`` the same way (an inner switch over the NoC
    stage). ``point_arrays = (addr, is_write, insn_per_req, core_app,
    scalars, policy_idx, noc_idx)`` — everything but ``archs``/
    ``nocs``/``structure``/``n_apps``/``probe_backend`` is traced, so
    one executable serves whole (policy, NoC, timing-geometry, trace)
    grids; ``n_apps`` sizes the per-app attribution accumulators
    (static — mixes with the same app count share executables).
    ``probe_backend`` is static too: unlike NoC models, probe backends
    lower structurally different round programs (XLA chain vs Pallas
    kernel), so each gets its own executable rather than a traced
    switch branch.

    ``telemetry`` (static, default ``None``) turns on windowed
    observability: the scan is restructured into an outer scan over
    ``rounds/window`` windows of an inner ``window``-round scan, and
    each outer step emits a *cumulative* snapshot of the stats + NoC
    carry (key ``"timeline"``, leading window axis). The per-round op
    sequence is identical to the flat scan, so final counters — and
    every ``SimResult`` derived from them — are bit-equal with and
    without telemetry; ``None`` never traces any of this, keeping the
    default executables byte-identical.
    """
    addr, is_write, insn_per_req, core_app, scalars, policy_idx, \
        noc_idx = point_arrays
    geom = TracedGeometry(structure, scalars)
    policies = [get_arch(a) for a in archs]
    noc_models = [get_noc(n) for n in nocs]
    state = (_l1_state(geom, policies), _l2_state(geom),
             _noc_state(geom, noc_models), jnp.int32(0),
             _init_stats(geom, n_apps, telemetry))
    steps = [functools.partial(_round, p, noc_models, noc_idx, geom,
                               insn_per_req, core_app,
                               probe_backend=probe_backend,
                               telemetry=telemetry)
             for p in policies]
    if len(steps) == 1:
        step = steps[0]
    else:
        def step(carry, xs):
            return jax.lax.switch(policy_idx, steps, carry, xs)
    if telemetry is None:
        (l1, l2, noc, t, stats), _ = jax.lax.scan(step, state,
                                                  (addr, is_write))
        return {**stats, "noc": noc}

    T = addr.shape[0]
    W = telemetry.window_for(T)
    xs = (addr.reshape((T // W, W) + addr.shape[1:]),
          is_write.reshape((T // W, W) + is_write.shape[1:]))

    def window_step(carry, xs_w):
        carry, _ = jax.lax.scan(step, carry, xs_w)
        _, _, noc_w, _, stats_w = carry
        return carry, {"stats": stats_w, "noc": noc_w}

    (l1, l2, noc, t, stats), snaps = jax.lax.scan(window_step, state, xs)
    return {**stats, "noc": noc, "timeline": snaps}


#: One compilation per (arch group, NoC group, trace shape, geometry
#: structure, app count, probe backend, telemetry config — ``None``
#: keys the exact pre-telemetry executables).
_simulate = jax.jit(_sim_core, static_argnums=(0, 1, 3, 4, 5, 6))

#: Batched form: vmap over a leading grid-point axis, still one
#: compilation. ``repro.core.sweep`` adds device sharding on top.
_simulate_batch = jax.jit(
    lambda archs, nocs, point_arrays, structure, n_apps, probe_backend: \
    jax.vmap(
        lambda pa: _sim_core(archs, nocs, pa, structure, n_apps,
                             probe_backend))(point_arrays),
    static_argnums=(0, 1, 3, 4, 5))


def _trace_arrays(trace: Trace):
    """One trace's traced leaves: (addr, is_write, insn, core_app)."""
    addr = jnp.asarray(trace.addr, jnp.int32)
    is_write = jnp.asarray(trace.is_write, bool)
    if np.ndim(trace.insn_per_req) == 0:
        insn = jnp.float32(trace.insn_per_req)
    else:
        insn = jnp.asarray(trace.insn_per_req, jnp.float32)
    core_app = jnp.asarray(trace.core_app_ids, jnp.int32)
    return addr, is_write, insn, core_app


def _point_arrays(trace_like, scalars, policy_idx=0, noc_idx=0):
    """Pack one grid point's traced leaves for :func:`_sim_core`."""
    addr, is_write, insn, core_app = trace_like
    return (addr, is_write, insn, core_app, scalars,
            jnp.int32(policy_idx), jnp.int32(noc_idx))


def round_signature(group: Tuple[str, ...], arch: str,
                    structure: GeomStructure,
                    round_shape: Tuple[int, int],
                    insn_shape: Tuple[int, ...] = (),
                    n_apps: int = 1,
                    noc_group: Tuple[str, ...] = ("ideal",),
                    noc: str = "ideal",
                    probe_backend: str = "lax"):
    """Abstract shape/dtype pytree of one scanned round of ``arch``.

    The round is evaluated (``jax.eval_shape`` — no compilation, no
    FLOPs) with the L1 state sized for the whole dataflow ``group``
    and the NoC state sized for the whole ``noc_group``, exactly as
    :func:`_sim_core` would compile them. Policies (and NoC models)
    that may stack into one executable must produce identical
    signatures — the carried state pytrees are what ``lax.switch``
    requires to line up — and ``repro.core.sweep.SweepGrid`` validates
    that with this function before it buckets a grid.
    ``insn_shape``/``n_apps`` mirror the trace's instruction-intensity
    shape and app count: mixes carry per-app accumulators in the same
    pytree. ``probe_backend`` selects the probe lowering — every
    backend must (and does) carry an identical state pytree, which this
    signature also certifies (the Pallas path abstract-evaluates here
    without running the kernel body).
    """
    C, m = round_shape
    policies = [get_arch(a) for a in group]
    noc_models = [get_noc(n) for n in noc_group]
    scalars = GeomScalars(*(jax.ShapeDtypeStruct((), jnp.float32)
                            for _ in GEOM_SCALAR_FIELDS))

    def one_round(scalars, addr, is_write, insn, core_app):
        geom = TracedGeometry(structure, scalars)
        state = (_l1_state(geom, policies), _l2_state(geom),
                 _noc_state(geom, noc_models), jnp.int32(0),
                 _init_stats(geom, n_apps))
        # evaluate the *selected* (arch, noc) member's round over state
        # sized for the full groups — members whose dataflow diverges
        # from the group produce a different signature here instead of
        # an opaque lax.switch failure inside the compiled executable
        new_state, _ = _round(get_arch(arch), [get_noc(noc)], jnp.int32(0),
                              geom, insn, core_app,
                              state, (addr, is_write),
                              probe_backend=probe_backend)
        return new_state

    out = jax.eval_shape(one_round, scalars,
                         jax.ShapeDtypeStruct((C, m), jnp.int32),
                         jax.ShapeDtypeStruct((C, m), jnp.bool_),
                         jax.ShapeDtypeStruct(insn_shape, jnp.float32),
                         jax.ShapeDtypeStruct((C,), jnp.int32))
    leaves, treedef = jax.tree.flatten(out)
    return treedef, tuple((l.shape, str(l.dtype)) for l in leaves)


def _summarize(stats, trace: Trace) -> SimResult:
    T, C, m = trace.addr.shape
    cycles_per_core = np.asarray(stats["cycles"], np.float64)  # (C,)
    if np.ndim(trace.insn_per_req) == 0:
        # unchanged scalar float path: pre-mix results stay bit-exact
        instructions = T * C * m * float(trace.insn_per_req)
    else:
        instructions = float(T * m * np.sum(trace.insn_vector))
    cycles = float(stats["cycles"].max())
    requests = float(stats["requests"])
    local = float(stats["local_hits"])
    remote = float(stats["remote_hits"])
    lat_n = float(stats["l1_lat_n"])

    ns = stats["noc"]
    busy = np.asarray(ns["link_busy"], np.float64)
    active = int((busy > 0).sum())
    delay_n = float(ns["delay_n"])
    noc_block = NocStats(
        flits_injected=float(ns["injected"]),
        flits_delivered=float(ns["delivered"]),
        flits_queued=float(np.asarray(ns["queue"], np.float64).sum()),
        mean_queue_delay=(float(ns["delay_sum"]) / delay_n if delay_n
                          else 0.0),
        max_link_util=(float(busy.max()) / cycles if busy.size else 0.0),
        mean_link_util=(float(busy.sum()) / (cycles * active) if active
                        else 0.0),
    )

    ids = trace.core_app_ids
    insn_vec = trace.insn_vector
    per_app = []
    for a in range(trace.n_apps):
        sel = ids == a
        k = int(sel.sum())
        per_app.append(AppStats(
            app=a, cores=k,
            instructions=float(T * m * insn_vec[sel].sum()),
            cycles=float(cycles_per_core[sel].max()),
            requests=float(T * k * m),
            local_hits=float(stats["app_local"][a]),
            remote_hits=float(stats["app_remote"][a]),
            l1_lat_sum=float(stats["app_lat_sum"][a]),
            l1_lat_n=float(stats["app_lat_n"][a])))

    return SimResult(
        ipc=instructions / cycles,
        # NaN when no load was ever fully served inside the L1 complex
        # (possible on very short or all-streaming traces)
        l1_latency=(float(stats["l1_lat_sum"]) / lat_n if lat_n
                    else float("nan")),
        local_hit_rate=local / requests,
        remote_hit_rate=remote / requests,
        l1_hit_rate=(local + remote) / requests,
        l2_accesses=float(stats["l2_accesses"]),
        dram_accesses=float(stats["dram"]),
        noc_flits=float(stats["noc_flits"]),
        cycles=cycles,
        instructions=instructions,
        per_app=tuple(per_app),
        noc=noc_block,
    )


def _check_arch(arch: str) -> None:
    if arch not in registered_archs():
        raise ValueError(f"arch must be one of {registered_archs()}")


def _check_noc(noc: str) -> None:
    if noc not in registered_nocs():
        raise ValueError(f"noc must be one of {registered_nocs()}")


def trace_kind(trace: Trace) -> tuple:
    """The executable-keying shape of a trace: (addr shape, insn shape,
    n_apps). Traces sharing a kind (and a dataflow group + geometry
    structure) share one compiled executable."""
    return (trace.addr.shape, np.shape(trace.insn_per_req), trace.n_apps)


def simulate(arch: str, trace: Trace,
             geom: GpuGeometry = PAPER_GEOMETRY, *,
             noc: str = "ideal",
             probe_backend: str = "lax",
             telemetry: Optional[TelemetryConfig] = None):
    """Run a trace through one architecture and summarize.

    ``noc`` selects the interconnect model (``repro.core.noc``); the
    default ``ideal`` reproduces the pre-NoC simulator bit-exactly.
    ``probe_backend`` selects the L1 probe lowering
    (``repro.core.probe``); every backend returns bit-identical
    results — the axis trades compile target (XLA vs Pallas/Mosaic)
    and speed, never semantics.

    ``telemetry`` (a :class:`~repro.core.telemetry.TelemetryConfig`)
    turns on windowed observability: the return becomes a
    ``(SimResult, repro.obs.SimTimeline)`` pair, with the
    :class:`SimResult` bit-equal to the ``telemetry=None`` run (the
    window restructuring preserves the per-round op sequence). The
    default ``None`` compiles and reuses exactly the pre-telemetry
    executable.
    """
    _check_arch(arch)
    _check_noc(noc)
    _check_probe_backend(probe_backend)
    if telemetry is not None:
        telemetry.window_for(trace.addr.shape[0])
    structure, scalars = split_geometry(geom)
    stats = jax.device_get(_simulate(
        (arch,), (noc,), _point_arrays(_trace_arrays(trace), scalars),
        structure, trace.n_apps, probe_backend, telemetry))
    if telemetry is None:
        return _summarize(stats, trace)
    from repro.obs.timeline import SimTimeline   # local: obs sits above core
    snaps = stats.pop("timeline")
    result = _summarize(stats, trace)
    tl = SimTimeline.from_snapshots(
        snaps, telemetry, rounds=trace.addr.shape[0],
        meta={"arch": arch, "noc": noc, "n_apps": trace.n_apps,
              "n_cores": trace.n_cores})
    return result, tl


def simulate_batch(arch: str, traces: Sequence[Trace],
                   geom: GpuGeometry = PAPER_GEOMETRY, *,
                   noc: str = "ideal",
                   probe_backend: str = "lax") -> List[SimResult]:
    """Run many same-shape traces through one architecture in one call.

    The traces are stacked on a new leading axis and the scanned
    simulation is ``jax.vmap``-ed over it, so the whole sweep is a single
    compiled executable (and a single device dispatch) regardless of how
    many traces are in the batch. All traces must share one
    :func:`trace_kind` — (T, C, m) shape, instruction-intensity shape,
    and app count; :func:`simulate_many` handles mixed kinds by
    grouping.
    """
    _check_arch(arch)
    _check_noc(noc)
    _check_probe_backend(probe_backend)
    if not traces:
        return []
    kinds = {trace_kind(t) for t in traces}
    if len(kinds) != 1:
        raise ValueError(
            f"simulate_batch needs same-shape, same-kind traces "
            f"((T, C, m), insn shape, n_apps), got {sorted(kinds)}; use "
            "simulate_many for mixed kinds")
    structure, scalars = split_geometry(geom)
    B = len(traces)
    n_apps = traces[0].n_apps
    addr = jnp.asarray(np.stack([t.addr for t in traces]), jnp.int32)
    is_write = jnp.asarray(np.stack([t.is_write for t in traces]), bool)
    if np.ndim(traces[0].insn_per_req) == 0:
        insn = jnp.asarray([t.insn_per_req for t in traces], jnp.float32)
    else:
        insn = jnp.asarray(np.stack([t.insn_per_req for t in traces]),
                           jnp.float32)
    core_app = jnp.asarray(np.stack([t.core_app_ids for t in traces]),
                           jnp.int32)
    batched = ((addr, is_write, insn, core_app,
                jax.tree.map(lambda s: jnp.broadcast_to(s, (B,)), scalars),
                jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32)))
    stats = jax.device_get(_simulate_batch((arch,), (noc,), batched,
                                           structure, n_apps,
                                           probe_backend))
    return [_summarize(jax.tree.map(lambda a: a[b], stats), traces[b])
            for b in range(len(traces))]


def simulate_many(arch: str, traces: Sequence[Trace],
                  geom: GpuGeometry = PAPER_GEOMETRY, *,
                  noc: str = "ideal",
                  probe_backend: str = "lax") -> List[SimResult]:
    """``simulate_batch`` over arbitrary traces: group by kind, preserve
    input order."""
    _check_arch(arch)
    _check_noc(noc)
    _check_probe_backend(probe_backend)
    groups: Dict[tuple, List[int]] = {}
    for i, t in enumerate(traces):
        groups.setdefault(trace_kind(t), []).append(i)
    out: List[SimResult] = [None] * len(traces)  # type: ignore[list-item]
    for idxs in groups.values():
        for i, r in zip(idxs, simulate_batch(
                arch, [traces[i] for i in idxs], geom, noc=noc,
                probe_backend=probe_backend)):
            out[i] = r
    return out
