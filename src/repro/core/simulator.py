"""Cache-hierarchy simulator: private / remote-sharing / decoupled / ATA.

One ``lax.scan`` step models one *round*: every core issues ``m`` memory
requests (one coalesced load instruction). Within a round the four
architectures differ only in routing and contention:

  private    : local L1 -> L2
  remote     : local L1 -> broadcast probes to cluster peers (NoC queue +
               probe service queue on the critical path) -> remote fetch
               or L2 *after* the probe round-trip  [Dublish'16, Ibrahim'19]
  decoupled  : address-sliced home cache; every request pays the home
               bank-port queue                       [Ibrahim'20/'21]
  ata        : aggregated tag array probed in parallel at zero added
               latency; only *known* remote hits cross the crossbar;
               writes are local-only with dirty-bit L2 diversion  [paper]

Latency composition feeds a warp-level hiding model to produce IPC, and
the L1-complex portion of each request's latency reproduces Fig. 10.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tagarray
from repro.core.contention import group_rank
from repro.core.geometry import GpuGeometry, PAPER_GEOMETRY

ARCHITECTURES = ("private", "remote", "decoupled", "ata")

#: Cycles to detect an L1 miss (tag check before dispatching onwards).
TAG_CHECK = 8


class Trace(NamedTuple):
    addr: np.ndarray       # (T, C, m) int32 line addresses
    is_write: np.ndarray   # (T, C, m) bool
    insn_per_req: float    # non-memory instructions amortized per request


class SimResult(NamedTuple):
    ipc: float
    l1_latency: float          # mean per-load L1-complex completion time
    local_hit_rate: float
    remote_hit_rate: float     # served by a peer L1 (0 for private/decoupled)
    l1_hit_rate: float         # served anywhere in the L1 complex
    l2_accesses: float
    dram_accesses: float
    noc_flits: float
    cycles: float
    instructions: float


def _l1_state(geom: GpuGeometry) -> tagarray.TagState:
    return tagarray.init_tag_state(geom.n_cores, geom.l1_sets, geom.l1_ways)


def _l2_state(geom: GpuGeometry) -> tagarray.TagState:
    return tagarray.init_tag_state(geom.l2_parts, geom.l2_sets, geom.l2_ways)


def _round(arch: str, geom: GpuGeometry, insn_per_req, state, xs):
    """One simulation round. state=(l1, l2, t, stats); xs=(addr, is_write)."""
    l1, l2, t, stats = state
    addr, is_write = xs                      # (C, m)
    C, m = addr.shape
    R = C * m
    addr = addr.reshape(R)
    is_write = is_write.reshape(R)
    core = jnp.repeat(jnp.arange(C, dtype=jnp.int32), m)
    cluster = core // geom.cluster_size
    self_slot = core % geom.cluster_size
    set_idx = (addr % geom.l1_sets).astype(jnp.int32)
    bank = set_idx % geom.l1_banks
    peers = (cluster[:, None] * geom.cluster_size
             + jnp.arange(geom.cluster_size, dtype=jnp.int32)[None, :])

    zero = jnp.zeros((R,), jnp.float32)
    noc_flits = 0.0

    occupancy = jnp.zeros((R,), jnp.float32)

    if arch == "private":
        hit, way, _ = tagarray.probe(l1, core, set_idx, addr)
        served = hit
        l1_time = jnp.where(hit, float(geom.lat_l1), float(TAG_CHECK))
        go_l2 = ~hit
        pre_l2 = jnp.full((R,), float(TAG_CHECK))
        fill_cache, fill_set = core, set_idx
        local_hits = hit
        remote_hits = jnp.zeros((R,), bool)
        l1 = tagarray.touch(l1, core, set_idx, way, t, hit,
                            set_dirty=is_write)

    elif arch == "decoupled":
        home = cluster * geom.cluster_size + (addr % geom.cluster_size)
        home_set = ((addr // geom.cluster_size) % geom.l1_sets).astype(jnp.int32)
        home_bank = home_set % geom.l1_banks
        hit, way, _ = tagarray.probe(l1, home, home_set, addr)
        # every request, hit or miss, pays the home bank-port queue; the
        # bank is a serial resource, so its busy time is also a
        # throughput (occupancy) bound warps cannot hide.
        key = home * geom.l1_banks + home_bank
        rank, size = group_rank(key, jnp.ones((R,), bool),
                                geom.n_cores * geom.l1_banks)
        delay = rank.astype(jnp.float32) * geom.svc_bank
        occupancy = size.astype(jnp.float32) * geom.svc_bank
        served = hit
        l1_time = jnp.where(hit,
                            geom.lat_l1 + geom.lat_home + delay,
                            TAG_CHECK + delay)
        go_l2 = ~hit
        pre_l2 = TAG_CHECK + delay
        fill_cache, fill_set = home, home_set
        local_hits = hit
        remote_hits = jnp.zeros((R,), bool)
        noc_flits = noc_flits + jnp.sum(hit) * geom.flits_per_line
        l1 = tagarray.touch(l1, home, home_set, way, t, hit,
                            set_dirty=is_write)

    elif arch == "remote":
        hit, way, _ = tagarray.probe(l1, core, set_idx, addr)
        miss = ~hit
        # broadcast probes: each miss queries all peers; probe service
        # queue per cluster + NoC load delay sit on the critical path.
        rank, n_miss = group_rank(cluster, miss, geom.n_clusters)
        probe_flits = n_miss.astype(jnp.float32) * (geom.cluster_size - 1)
        noc_delay = probe_flits / geom.noc_bw
        probe_wait = (geom.lat_probe + rank.astype(jnp.float32)
                      * geom.svc_probe + noc_delay)
        rhits, rways, _ = tagarray.probe_many(l1, peers, set_idx, addr)
        rhits = rhits & (jnp.arange(geom.cluster_size)[None, :]
                         != self_slot[:, None])
        remote_hit = miss & rhits.any(axis=-1)
        src_slot = jnp.argmax(rhits, axis=-1)
        src_cache = cluster * geom.cluster_size + src_slot
        prank, psize = group_rank(src_cache, remote_hit, geom.n_cores)
        xfer = geom.lat_xbar + prank.astype(jnp.float32) * geom.svc_port
        # every peer cache's tag port serves every probe in the cluster
        occupancy = jnp.where(
            miss, n_miss.astype(jnp.float32) * geom.svc_probe, 0.0)
        occupancy = jnp.maximum(
            occupancy,
            jnp.where(remote_hit,
                      psize.astype(jnp.float32) * geom.svc_port, 0.0))
        served = hit | remote_hit
        l1_time = jnp.where(hit, float(geom.lat_l1),
                            TAG_CHECK + probe_wait
                            + jnp.where(remote_hit, xfer, 0.0))
        go_l2 = miss & ~remote_hit
        pre_l2 = TAG_CHECK + probe_wait          # probes extend L2 path
        fill_cache, fill_set = core, set_idx
        local_hits = hit
        remote_hits = remote_hit
        noc_flits = (noc_flits + jnp.sum(miss) * (geom.cluster_size - 1)
                     + jnp.sum(remote_hit) * geom.flits_per_line)
        l1 = tagarray.touch(l1, core, set_idx, way, t, hit,
                            set_dirty=is_write)

    elif arch == "ata":
        # aggregated tag array: all cluster tags compared in parallel,
        # zero added latency, zero probe traffic.
        hits, ways, dirt = tagarray.probe_many(l1, peers, set_idx, addr)
        is_self = (jnp.arange(geom.cluster_size)[None, :]
                   == self_slot[:, None])
        local_hit = (hits & is_self).any(axis=-1)
        way = jnp.where(local_hit,
                        jnp.take_along_axis(
                            ways, self_slot[:, None], axis=1)[:, 0],
                        tagarray.probe(l1, core, set_idx, addr)[1])
        rmask = hits & ~is_self
        any_remote = rmask.any(axis=-1)
        src_slot = jnp.argmax(rmask, axis=-1)
        src_cache = cluster * geom.cluster_size + src_slot
        src_dirty = jnp.take_along_axis(dirt, src_slot[:, None],
                                        axis=1)[:, 0]
        # writes are local-only (paper coherence rule); dirty remote
        # copies divert the read to L2.
        remote_ok = (~is_write) & (~local_hit) & any_remote & (~src_dirty)
        prank, psize = group_rank(src_cache, remote_ok, geom.n_cores)
        # only *actual* remote hits occupy the remote data port — the
        # filtering that is the paper's core contention win.
        occupancy = jnp.where(
            remote_ok, psize.astype(jnp.float32) * geom.svc_port, 0.0)
        served = local_hit | remote_ok
        l1_time = jnp.where(
            local_hit, float(geom.lat_l1),
            jnp.where(remote_ok,
                      geom.lat_l1 + geom.lat_xbar
                      + prank.astype(jnp.float32) * geom.svc_port,
                      float(TAG_CHECK)))
        go_l2 = ~served
        pre_l2 = jnp.full((R,), float(TAG_CHECK))
        fill_cache, fill_set = core, set_idx
        local_hits = local_hit
        remote_hits = remote_ok
        noc_flits = noc_flits + jnp.sum(remote_ok) * geom.flits_per_line
        l1 = tagarray.touch(l1, core, set_idx, way, t, local_hit,
                            set_dirty=is_write)
    else:  # pragma: no cover
        raise ValueError(f"unknown architecture {arch!r}")

    # ---- L2 stage ---------------------------------------------------------
    l2_part = (addr % geom.l2_parts).astype(jnp.int32)
    l2_set = ((addr // geom.l2_parts) % geom.l2_sets).astype(jnp.int32)
    l2_hit, l2_way, _ = tagarray.probe(l2, l2_part, l2_set, addr)
    l2_rank, l2_size = group_rank(l2_part, go_l2, geom.l2_parts)
    l2_time = (geom.lat_l2 + l2_rank.astype(jnp.float32) * geom.svc_l2
               + jnp.where(l2_hit, 0.0, float(geom.lat_dram)))
    occupancy = jnp.maximum(
        occupancy,
        jnp.where(go_l2, l2_size.astype(jnp.float32) * geom.svc_l2, 0.0))
    l2 = tagarray.touch(l2, l2_part, l2_set, l2_way, t, go_l2 & l2_hit)
    l2, _ = tagarray.fill(l2, l2_part, l2_set, l2_way, addr, t,
                          go_l2 & ~l2_hit)
    noc_flits = noc_flits + jnp.sum(go_l2) * geom.flits_per_line

    # ---- L1 fill on L2 return (and on remote fetch: replicate locally) ----
    fill_mask = go_l2 | remote_hits
    _, fway, _ = tagarray.probe(l1, fill_cache, fill_set, addr)
    l1, wb = tagarray.fill(l1, fill_cache, fill_set, fway, addr, t,
                           fill_mask, dirty=is_write)
    noc_flits = noc_flits + jnp.sum(wb) * geom.flits_per_line

    # ---- timing ------------------------------------------------------------
    latency = jnp.where(served, l1_time, pre_l2 + l2_time)     # (R,)
    # Warp multithreading hides individual request latencies; the core's
    # sustained pace is set by *mean* outstanding latency per load, while
    # serial-resource occupancy is a hard throughput bound (max over m).
    per_core_lat = latency.reshape(C, m).mean(axis=1)
    per_core_occ = occupancy.reshape(C, m).max(axis=1)
    pace = m * insn_per_req / geom.issue_rate
    round_cost = jnp.maximum(jnp.maximum(pace, per_core_occ),
                             per_core_lat / geom.hide)         # (C,)

    # Fig.10 metric: completion time of the L1 accesses of one load
    # instruction, over loads fully served by the L1 complex.
    all_served = served.reshape(C, m).all(axis=1)
    l1_complete = l1_time.reshape(C, m).max(axis=1)

    stats = {
        "cycles": stats["cycles"] + round_cost,
        "l1_lat_sum": stats["l1_lat_sum"]
        + jnp.sum(jnp.where(all_served, l1_complete, 0.0)),
        "l1_lat_n": stats["l1_lat_n"] + jnp.sum(all_served),
        "local_hits": stats["local_hits"] + jnp.sum(local_hits),
        "remote_hits": stats["remote_hits"] + jnp.sum(remote_hits),
        "requests": stats["requests"] + R,
        "l2_accesses": stats["l2_accesses"] + jnp.sum(go_l2),
        "dram": stats["dram"] + jnp.sum(go_l2 & ~l2_hit),
        "noc_flits": stats["noc_flits"] + noc_flits,
    }
    return (l1, l2, t + 1, stats), None


def _init_stats(geom: GpuGeometry) -> Dict[str, jnp.ndarray]:
    z = jnp.float32(0.0)
    return {"cycles": jnp.zeros((geom.n_cores,), jnp.float32),
            "l1_lat_sum": z, "l1_lat_n": z, "local_hits": z,
            "remote_hits": z, "requests": z, "l2_accesses": z,
            "dram": z, "noc_flits": z}


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def _simulate(arch: str, trace_arrays, insn_per_req: float,
              geom: GpuGeometry):
    addr, is_write = trace_arrays
    state = (_l1_state(geom), _l2_state(geom), jnp.int32(0),
             _init_stats(geom))
    step = functools.partial(_round, arch, geom, insn_per_req)
    (l1, l2, t, stats), _ = jax.lax.scan(step, state, (addr, is_write))
    return stats


def simulate(arch: str, trace: Trace,
             geom: GpuGeometry = PAPER_GEOMETRY) -> SimResult:
    """Run a trace through one architecture and summarize."""
    if arch not in ARCHITECTURES:
        raise ValueError(f"arch must be one of {ARCHITECTURES}")
    addr = jnp.asarray(trace.addr, jnp.int32)
    is_write = jnp.asarray(trace.is_write, bool)
    stats = jax.device_get(
        _simulate(arch, (addr, is_write), float(trace.insn_per_req), geom))
    T, C, m = trace.addr.shape
    instructions = T * C * m * trace.insn_per_req
    cycles = float(stats["cycles"].max())
    requests = float(stats["requests"])
    local = float(stats["local_hits"])
    remote = float(stats["remote_hits"])
    return SimResult(
        ipc=instructions / cycles,
        l1_latency=float(stats["l1_lat_sum"]) / float(stats["l1_lat_n"]),
        local_hit_rate=local / requests,
        remote_hit_rate=remote / requests,
        l1_hit_rate=(local + remote) / requests,
        l2_accesses=float(stats["l2_accesses"]),
        dram_accesses=float(stats["dram"]),
        noc_flits=float(stats["noc_flits"]),
        cycles=cycles,
        instructions=instructions,
    )
