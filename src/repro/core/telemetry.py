"""Opt-in telemetry: counter registry, windows, exact histograms.

Both scan hot paths (``repro.core.simulator`` and
``repro.serving.engine``) fold every per-round signal — hits, remote
probes, per-app latency sums, NoC queue depth, link flits — into their
carries and keep only end-of-run totals. This module is the shared
vocabulary for *keeping* the time axis:

* :class:`TelemetryConfig` — a frozen, hashable config passed as a
  **static** ``telemetry=`` argument to ``simulate`` /
  ``SweepGrid.run`` / ``serve_stream``. ``None`` (the default) keeps
  the existing executables byte-identical — the telemetry branch is
  never traced, so goldens and compile caches are untouched (tier-1
  asserted). A config makes the scans additionally emit per-*window*
  cumulative counter snapshots (window-strided: memory is
  ``rounds/window x counters``, never ``rounds x counters``).
* :class:`Counter` + :data:`SIM_COUNTERS` / :data:`SERVE_COUNTERS` —
  the declarative registry naming every emitted counter (unit, axis,
  description) and mapping it onto the carry/emission field it already
  rides in. Exporters (``repro.obs``) iterate the registry instead of
  hard-coding field names.
* Exact latency histograms — int32 bincount counters in the carries.
  The serving engine's cost model is integral by default, so its
  histogram is value-resolved (one bucket per modeled cycle) and
  quantiles reconstruct ``np.percentile`` **exactly**
  (:func:`hist_quantile` replicates numpy's linear interpolation bit
  for bit); the simulator's L1-complete latencies are fractional, so
  its histogram is log-2-bucketed (:func:`log2_bucket`) and quantile
  reads are exact at bucket granularity (:func:`hist_quantile_edges`
  returns the conservative upper edge).

The window contract: ``rounds % window == 0`` (checked with a
divisor-suggesting error). Snapshots are *cumulative*, so the final
snapshot equals the run total by construction and per-window deltas
telescope back to it exactly — every f32 counter value is exactly
representable in f64, consecutive-snapshot differences are exact, and
their f64 sum reproduces ``total - 0`` with no rounding (the
conservation guarantee ``repro.obs.timeline`` checks).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "TelemetryConfig", "Counter", "SIM_COUNTERS", "SERVE_COUNTERS",
    "log2_bucket", "log2_edges", "hist_quantile", "hist_quantile_edges",
    "serving_hist_bins",
]


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static telemetry knob (hashable: part of the executable key).

    ``window`` is the snapshot stride in *rounds* (simulator) or
    *admission rounds* (serving engine). ``histograms`` adds the
    latency-histogram counter to the carry; ``sim_hist_bins`` sizes the
    simulator's log-2 bucket array (bucket ``i`` covers
    ``[2^i, 2^(i+1))`` cycles, bucket 0 also absorbs sub-cycle
    latencies, the last bucket absorbs overflow).
    """
    window: int = 32
    histograms: bool = True
    sim_hist_bins: int = 32

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.sim_hist_bins < 2:
            raise ValueError(
                f"sim_hist_bins must be >= 2, got {self.sim_hist_bins}")

    def window_for(self, rounds: int) -> int:
        """Validate the window against a run length and return it."""
        if rounds % self.window:
            divisors = [d for d in range(1, rounds + 1)
                        if rounds % d == 0]
            near = min(divisors, key=lambda d: abs(d - self.window))
            raise ValueError(
                f"telemetry window {self.window} must divide the run "
                f"length {rounds} (nearest divisor: {near})")
        return self.window


@dataclasses.dataclass(frozen=True)
class Counter:
    """One registered telemetry counter.

    ``field`` names the carry/emission field the counter maps onto —
    ``"noc.<key>"`` reaches into the carried NoC state dict. ``axis``
    is the trailing shape semantic: ``scalar`` (0-d), ``core`` /
    ``app`` / ``link`` (simulator), ``shard`` / ``tenant`` (serving),
    ``bucket`` (histograms). ``cumulative`` counters snapshot a
    monotone running sum (per-window series are deltas); gauges
    (``cumulative=False``) snapshot an instantaneous value (per-window
    series are samples).
    """
    name: str
    unit: str
    axis: str
    field: str
    description: str
    cumulative: bool = True


#: Simulator counters, mapped onto the ``lax.scan`` carry of
#: ``repro.core.simulator._round`` (the ``stats`` dict + carried NoC
#: state). Window snapshots expose exactly these.
SIM_COUNTERS: Tuple[Counter, ...] = (
    Counter("cycles", "cycles", "core", "cycles",
            "per-core accumulated round cost (completion clock)"),
    Counter("requests", "requests", "scalar", "requests",
            "memory requests issued"),
    Counter("local_hits", "requests", "scalar", "local_hits",
            "requests served by the issuing core's own L1"),
    Counter("remote_hits", "requests", "scalar", "remote_hits",
            "requests served by a peer L1 in the cluster"),
    Counter("l2_accesses", "requests", "scalar", "l2_accesses",
            "requests escalated to the shared L2"),
    Counter("dram", "requests", "scalar", "dram",
            "L2 misses that went to DRAM"),
    Counter("noc_flits", "flits", "scalar", "noc_flits",
            "interconnect flits injected by the L1 complex"),
    Counter("l1_lat_sum", "cycles", "scalar", "l1_lat_sum",
            "sum of L1-complex completion times over served loads"),
    Counter("l1_lat_n", "loads", "scalar", "l1_lat_n",
            "loads fully served inside the L1 complex"),
    Counter("app_local", "requests", "app", "app_local",
            "per-app local L1 hits (mix attribution)"),
    Counter("app_remote", "requests", "app", "app_remote",
            "per-app remote L1 hits (mix attribution)"),
    Counter("app_lat_sum", "cycles", "app", "app_lat_sum",
            "per-app L1-complete latency sum"),
    Counter("app_lat_n", "loads", "app", "app_lat_n",
            "per-app loads fully served in the L1 complex"),
    Counter("noc.injected", "flits", "scalar", "noc.injected",
            "flits injected into the interconnect model"),
    Counter("noc.delivered", "flits", "scalar", "noc.delivered",
            "flits delivered by the interconnect model"),
    Counter("noc.delay_sum", "cycles", "scalar", "noc.delay_sum",
            "summed NoC queueing delay over crossing requests"),
    Counter("noc.delay_n", "requests", "scalar", "noc.delay_n",
            "requests that crossed the interconnect"),
    Counter("noc.link_flits", "flits", "link", "noc.link_flits",
            "per-link flits carried"),
    Counter("noc.link_busy", "cycles", "link", "noc.link_busy",
            "per-link busy cycles"),
    Counter("noc.queue", "flits", "link", "noc.queue",
            "per-port queue depth at window end (backpressure gauge)",
            cumulative=False),
    Counter("lat_hist", "loads", "bucket", "lat_hist",
            "log2-bucketed L1-complete latency histogram"),
)

#: Serving-engine counters, derived from the per-sub-round emission
#: grids ``serve_stream`` already streams to the host (plus the
#: device-side latency bincount).
SERVE_COUNTERS: Tuple[Counter, ...] = (
    Counter("admitted", "requests", "shard", "admitted",
            "requests admitted (valid slots) per shard"),
    Counter("local_hits", "blocks", "shard", "nl",
            "prefix blocks reused from the local pool"),
    Counter("remote_hits", "blocks", "shard", "nr",
            "prefix blocks fetched from a peer shard"),
    Counter("recomputed", "blocks", "shard", "nc",
            "prefix blocks recomputed (prefill)"),
    Counter("latency_sum", "cycles", "shard", "lat",
            "summed modeled request latency per shard"),
    Counter("cycles", "cycles", "scalar", "cycles",
            "summed per-admission-round critical paths"),
    Counter("probe_messages", "messages", "scalar", "pm",
            "broadcast directory probes sent"),
    Counter("tenant_requests", "requests", "tenant", "tenant_requests",
            "requests admitted per tenant"),
    Counter("tenant_blocks", "blocks", "tenant", "tenant_blocks",
            "prefix blocks walked per tenant"),
    Counter("lat_hist", "requests", "bucket", "lat_hist",
            "value-resolved modeled-latency histogram (1 cycle/bucket)"),
)


# ---------------------------------------------------------------------------
# histogram helpers
# ---------------------------------------------------------------------------

def log2_bucket(x, bins: int):
    """Device-side log2 bucket index of positive latencies (jnp).

    Bucket ``i`` covers ``[2^i, 2^(i+1))``; values below 1 land in
    bucket 0 and values at or above ``2^(bins-1)`` clip into the last
    bucket. Powers of two are exact in float32, so bucket edges are
    crisp.
    """
    import jax.numpy as jnp
    b = jnp.floor(jnp.log2(jnp.maximum(x, 1.0)))
    return jnp.clip(b, 0, bins - 1).astype(jnp.int32)


def log2_edges(bins: int) -> np.ndarray:
    """(bins,) float64 upper edges of the log2 buckets (2^(i+1))."""
    return 2.0 ** (np.arange(bins, dtype=np.float64) + 1.0)


def serving_hist_bins(max_lat: float) -> int:
    """Bucket count for a value-resolved serving histogram.

    One bucket per modeled cycle up to the engine's per-request latency
    bound (``_check_headroom``'s ``max_lat``), plus an overflow bucket
    for non-ideal NoC delay beyond the base-cost bound.
    """
    return int(math.ceil(max_lat)) + 2


def _np_lerp(a: float, b: float, t: float) -> float:
    """numpy's percentile interpolation, replicated bit for bit."""
    diff = b - a
    if t >= 0.5:
        return b - diff * (1.0 - t)
    return a + diff * t


def hist_quantile(counts, q: float) -> float:
    """Exact ``np.percentile(values, q)`` from a value-resolved histogram.

    ``counts[v]`` is the number of observations with value exactly
    ``v`` (the serving engine's integral cost model quantized at one
    modeled cycle per bucket). Reconstructs numpy's default linear
    interpolation between order statistics, including its asymmetric
    lerp, so the result is bit-identical to materializing the array.
    """
    counts = np.asarray(counts, np.int64)
    n = int(counts.sum())
    if n == 0:
        return 0.0
    pos = (q / 100.0) * (n - 1)
    i = int(np.floor(pos))
    t = pos - i
    cum = np.cumsum(counts)
    lo = int(np.searchsorted(cum, i, side="right"))
    if t == 0.0:
        return float(lo)
    hi = int(np.searchsorted(cum, i + 1, side="right"))
    return _np_lerp(float(lo), float(hi), t)


def hist_quantile_edges(counts, q: float,
                        edges: Optional[np.ndarray] = None) -> float:
    """Conservative quantile from a bucketed histogram (upper edge).

    For log2-bucketed histograms the order statistic's bucket is exact
    but the value inside it is not; return the bucket's upper edge so
    the reported pXX is a guaranteed upper bound. ``edges`` defaults to
    the log2 edges sized to ``counts``.
    """
    counts = np.asarray(counts, np.int64)
    n = int(counts.sum())
    if n == 0:
        return 0.0
    if edges is None:
        edges = log2_edges(counts.size)
    # order statistic at ceil(q/100 * (n-1)): the conservative side
    pos = int(math.ceil((q / 100.0) * (n - 1)))
    cum = np.cumsum(counts)
    b = int(np.searchsorted(cum, pos, side="right"))
    return float(edges[min(b, counts.size - 1)])
