"""Deprecated shim — the workload layer moved to ``repro.core.trace``.

This module was the seed-era single-app monolith; PR 4 split it into a
composable package:

  repro.core.trace.apps        the calibrated AppParams table
  repro.core.trace.generators  make_trace / kernel_params / int32 guard
  repro.core.trace.mix         WorkloadMix multi-tenant composition

Every public (and test-visible private) name re-exports below so old
imports keep working unchanged; new code should import from
``repro.core.trace``. This shim will stay for at least one release
cycle — importing it raises a :class:`DeprecationWarning` so callers
migrate before it goes.
"""
import warnings

warnings.warn(
    "repro.core.workloads is a deprecated shim; import from "
    "repro.core.trace (apps/generators/mix) instead",
    DeprecationWarning, stacklevel=2)

from repro.core.trace.apps import (APPS, HIGH_LOCALITY, LOW_LOCALITY,  # noqa: F401,E402
                                   AppParams)
from repro.core.trace.generators import (_SHARED_BASE, _PRIVATE_BASE,  # noqa: F401,E402
                                         _STREAM_BASE, _kernel_params,
                                         _require_int32, _stable_seed,
                                         app_kernels, kernel_params,
                                         make_trace)

__all__ = [
    "APPS", "HIGH_LOCALITY", "LOW_LOCALITY", "AppParams",
    "app_kernels", "kernel_params", "make_trace",
]
