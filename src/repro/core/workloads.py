"""Synthetic workload generators calibrated to the paper's benchmarks.

Real Rodinia/Tango/Polybench address traces are not available offline, so
each application is modeled as a parameterized request-stream generator
whose locality structure matches the paper's classification (Section IV):
five high inter-core-locality apps (``b+tree, cfd, doitgen, conv3d, SN``)
and five low-locality apps (incl. ``HS3D, sradv1``). Parameters:

  shared_frac    probability a request targets the cluster-shared pool
                 (inter-core locality); the rest go to a per-core pool
  ws_shared      shared working set, in 128B lines (vs 512 lines/L1)
  ws_private     per-core private working set, in lines
  hot_frac/size  fraction of shared accesses hitting a small hot subset
                 (drives same-line / same-home contention)
  stream_frac    streaming (compulsory-miss) fraction
  coalesced      whether a load's m requests are consecutive lines
  write_frac     store fraction
  insn_per_req   amortized instructions per memory request (intensity)
  n_kernels      kernels per app (Fig. 9 per-kernel diversity)

Apps are *calibrated proxies*: EXPERIMENTS.md §Repro reports both the
paper-target numbers and sensitivity sweeps over these parameters.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List

import numpy as np

from repro.core.simulator import Trace

#: Disjoint address regions (line numbers).
_SHARED_BASE = 0
_PRIVATE_BASE = 1 << 20
_STREAM_BASE = 1 << 26


@dataclasses.dataclass(frozen=True)
class AppParams:
    name: str
    high_locality: bool
    shared_frac: float
    ws_shared: int
    ws_private: int
    hot_frac: float = 0.0
    hot_size: int = 64
    stream_frac: float = 0.05
    coalesced: float = 0.8
    write_frac: float = 0.08
    insn_per_req: float = 6.0
    n_kernels: int = 4
    rounds: int = 1536
    m: int = 4


APPS: Dict[str, AppParams] = {p.name: p for p in [
    # ---- high inter-core locality ----------------------------------------
    AppParams("b+tree", True, shared_frac=0.82, ws_shared=1024,
              ws_private=224, hot_frac=0.05, hot_size=48, coalesced=0.75,
              write_frac=0.04, insn_per_req=26.0, n_kernels=2, m=2),
    AppParams("cfd", True, shared_frac=0.86, ws_shared=1024,
              ws_private=288, hot_frac=0.05, hot_size=96, coalesced=0.85,
              write_frac=0.10, insn_per_req=26.0, n_kernels=5, m=2),
    AppParams("doitgen", True, shared_frac=0.72, ws_shared=1024,
              ws_private=320, hot_frac=0.75, hot_size=8, coalesced=0.85,
              write_frac=0.06, insn_per_req=10.0, n_kernels=3),
    AppParams("conv3d", True, shared_frac=0.68, ws_shared=1152,
              ws_private=352, hot_frac=0.50, hot_size=32, coalesced=0.85,
              write_frac=0.08, insn_per_req=11.0, n_kernels=5),
    AppParams("SN", True, shared_frac=0.76, ws_shared=1344,
              ws_private=288, hot_frac=0.45, hot_size=48, coalesced=0.8,
              write_frac=0.05, insn_per_req=13.0, n_kernels=8),
    # ---- low inter-core locality ------------------------------------------
    AppParams("HS3D", False, shared_frac=0.10, ws_shared=512,
              ws_private=448, stream_frac=0.25, coalesced=0.9,
              write_frac=0.15, insn_per_req=7.0, n_kernels=6),
    AppParams("sradv1", False, shared_frac=0.08, ws_shared=384,
              ws_private=512, stream_frac=0.20, coalesced=0.9,
              write_frac=0.18, insn_per_req=6.0, n_kernels=15),
    AppParams("gaussian", False, shared_frac=0.12, ws_shared=448,
              ws_private=416, stream_frac=0.15, coalesced=0.85,
              write_frac=0.12, insn_per_req=8.0, n_kernels=3),
    AppParams("lud", False, shared_frac=0.14, ws_shared=512,
              ws_private=480, stream_frac=0.10, coalesced=0.8,
              write_frac=0.10, insn_per_req=7.0, n_kernels=4),
    AppParams("nw", False, shared_frac=0.06, ws_shared=320,
              ws_private=544, stream_frac=0.30, coalesced=0.75,
              write_frac=0.14, insn_per_req=6.0, n_kernels=2),
]}

HIGH_LOCALITY = [n for n, p in APPS.items() if p.high_locality]
LOW_LOCALITY = [n for n, p in APPS.items() if not p.high_locality]


def _stable_seed(*parts) -> int:
    return zlib.crc32("|".join(str(p) for p in parts).encode()) & 0x7FFFFFFF


def _require_int32(addr: np.ndarray) -> np.ndarray:
    """Narrow int64 addresses to the simulator's int32, refusing to wrap.

    The streaming region grows monotonically from ``_STREAM_BASE``; very
    long traces (or a bumped ``_STREAM_BASE``) could silently overflow
    into negative line numbers on ``astype(np.int32)``, corrupting set
    hashing and region disjointness.
    """
    lo, hi = int(addr.min()), int(addr.max())
    info = np.iinfo(np.int32)
    if lo < 0 or hi > info.max:
        raise ValueError(
            f"trace addresses span [{lo}, {hi}], outside int32 "
            f"[0, {info.max}]; shrink rounds/working sets or widen the "
            "simulator address type")
    return addr.astype(np.int32)


def _kernel_params(app: AppParams, kernel: int) -> AppParams:
    """Deterministic per-kernel jitter around the app's parameters."""
    rng = np.random.default_rng(_stable_seed(app.name, kernel))
    scale = lambda lo, hi: float(rng.uniform(lo, hi))
    return dataclasses.replace(
        app,
        shared_frac=float(np.clip(app.shared_frac * scale(0.6, 1.25), 0, .95)),
        ws_shared=max(64, int(app.ws_shared * scale(0.5, 1.6))),
        ws_private=max(64, int(app.ws_private * scale(0.7, 1.3))),
        hot_frac=float(np.clip(app.hot_frac * scale(0.5, 1.5), 0, 0.8)),
        stream_frac=float(np.clip(app.stream_frac * scale(0.5, 1.8), 0, .5)),
        insn_per_req=app.insn_per_req * scale(0.8, 1.25),
    )


def make_trace(app: AppParams, *, n_cores: int = 30, kernel: int = 0,
               seed: int = 0) -> Trace:
    """Generate one kernel's request trace for all cores."""
    p = _kernel_params(app, kernel) if kernel else app
    rng = np.random.default_rng(_stable_seed(app.name, kernel, seed))
    T, C, m = p.rounds, n_cores, p.m

    # Per-(round, core) load classification.
    u = rng.random((T, C))
    is_shared = u < p.shared_frac
    is_stream = (u >= p.shared_frac) & (u < p.shared_frac + p.stream_frac)

    base = np.empty((T, C), np.int64)
    # shared pool (common to all cores in a cluster -> inter-core locality)
    hot = rng.random((T, C)) < p.hot_frac
    shared_addr = np.where(
        hot,
        rng.integers(0, p.hot_size, (T, C)),
        rng.integers(0, p.ws_shared, (T, C)))
    base[is_shared] = (_SHARED_BASE + shared_addr)[is_shared]
    # streaming: monotonically advancing per core (compulsory misses)
    stream = (_STREAM_BASE + np.arange(C)[None, :] * (1 << 16)
              + np.cumsum(np.ones((T, C), np.int64), axis=0) * m)
    base[is_stream] = stream[is_stream]
    # private pool
    priv = (_PRIVATE_BASE + np.arange(C)[None, :] * (1 << 14)
            + rng.integers(0, p.ws_private, (T, C)))
    rest = ~(is_shared | is_stream)
    base[rest] = priv[rest]

    # Coalescing: a load's m requests are consecutive lines (regular apps)
    # or independent re-samples from the same pool (irregular apps).
    coal = rng.random((T, C, 1)) < p.coalesced
    consec = base[:, :, None] + np.arange(m)[None, None, :]
    hot_s = rng.random((T, C, m)) < p.hot_frac
    resample_shared = _SHARED_BASE + np.where(
        hot_s,
        rng.integers(0, p.hot_size, (T, C, m)),
        rng.integers(0, p.ws_shared, (T, C, m)))
    resample_priv = (_PRIVATE_BASE + np.arange(C)[None, :, None] * (1 << 14)
                     + rng.integers(0, p.ws_private, (T, C, m)))
    scattered = np.where(is_shared[:, :, None], resample_shared,
                         resample_priv)
    scattered = np.where(is_stream[:, :, None], consec, scattered)
    addr = np.where(coal, consec, scattered).astype(np.int64)

    is_write = rng.random((T, C, m)) < p.write_frac
    return Trace(addr=_require_int32(addr), is_write=is_write,
                 insn_per_req=p.insn_per_req)


def app_kernels(name: str) -> List[int]:
    return list(range(APPS[name].n_kernels))
