"""Device-sharded, multi-axis parameter-grid sweep engine.

:class:`SweepGrid` takes a cartesian grid over four axes —

    archs   : architecture-policy names (``repro.core.arch`` registry)
    geoms   : :class:`GpuGeometry` points
    traces  : :class:`Trace` points (e.g. all kernels of an app)
    nocs    : interconnect-model names (``repro.core.noc`` registry;
              defaults to the bit-exact ``ideal``)
    probe_backends : L1 probe lowerings (``repro.core.probe``;
              defaults to the fused ``lax`` path — backends return
              bit-identical results but compile separate executables)

— and runs every point through the round-pipeline simulator while
compiling as few executables as possible:

* **policy stacking** — architectures whose policies share a
  ``stack_key`` (identical round dataflow, e.g. ``ata``/``ata_fifo``/
  ``ata_bypass``) are compiled into *one* executable; the active policy
  is selected per grid point by a traced index (``lax.switch`` inside
  the scanned round). Note the tradeoff: under ``vmap`` a batched
  switch index lowers to *compute-all-branches-and-select*, so a
  stacked bucket pays roughly group-size x the per-round FLOPs in
  exchange for one compilation and one dispatch — a good trade while
  compile time dominates (small grids, wide families, CI smoke) but
  worth splitting into per-policy grids when a single stacked bucket
  grows runtime-bound.
* **geometry batching** — timing scalars (latencies, service times,
  rates) are traced (:class:`repro.core.geometry.GeomScalars`), so
  geometries that differ only in scalars share an executable; structure
  fields (core/set/way counts) fix array shapes and group points.
* **device sharding** — each execution bucket's stacked point axis is
  padded to the device count and sharded with
  ``repro.sharding.compat.shard_map``, so an N-device host runs N grid
  points at a time per dispatch.

An executable is therefore keyed by (arch dataflow group, NoC model
group, geometry structure, trace *kind* = shape + insn shape + app
count, probe backend, padded batch size, device count); everything else — policy
choice, NoC choice, timing scalars, addresses, instruction mix,
app-to-core assignment — is data. NoC models stack exactly like
policy families (``NocModel.stack_key``; the built-ins all share one
family), so an (arch zoo x {ideal, crossbar, ring}) grid compiles one
executable per architecture family, not per topology.
Multi-tenant mixes (``repro.core.trace.WorkloadMix``) are ordinary
grid points: same-shape mixes share one executable per dataflow group.
Results are bit-identical to running :func:`repro.core.simulate`
per point (a tier-1 test asserts this), so figures can move freely
between the two.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import (GeomStructure, GpuGeometry, PAPER_GEOMETRY,
                                 geom_structure, split_geometry)
from repro.core.simulator import (SimResult, Trace, _check_arch, _check_noc,
                                  _sim_core, _summarize, round_signature,
                                  trace_kind)
from repro.core.telemetry import TelemetryConfig
from repro.core.arch import get_arch, registered_archs
from repro.core.noc import get_noc, registered_nocs
from repro.core.probe import check_probe_backend
from repro.sharding.compat import make_mesh_1d, shard_map, shard_map_norep
from jax.sharding import PartitionSpec as P


class SweepPoint(NamedTuple):
    """One (arch, geometry, trace[, noc[, probe_backend]]) grid point.

    ``noc`` selects the interconnect model (``repro.core.noc``); the
    default ``ideal`` keeps every pre-NoC grid bit-exact.
    ``probe_backend`` selects the L1 probe lowering
    (``repro.core.probe``); backends return bit-identical results, so
    the axis only changes which executable serves the point.
    """
    arch: str
    geom: GpuGeometry
    trace: Trace
    noc: str = "ideal"
    probe_backend: str = "lax"


@dataclasses.dataclass(frozen=True)
class SweepReport:
    """Execution accounting for one :meth:`SweepGrid.run`.

    ``n_executables`` counts the distinct compiled programs the run
    dispatched to; ``n_compiles`` counts how many of those were built
    fresh this run (the rest were warm in the process-wide cache).
    """
    n_points: int
    n_executables: int
    n_compiles: int
    n_devices: int
    wall_s: float


class SweepRun(NamedTuple):
    results: List[SimResult]     # aligned with SweepGrid.points
    report: SweepReport
    #: per-point ``repro.obs.SimTimeline`` list (aligned with points)
    #: when :meth:`SweepGrid.run` was given a telemetry config
    timelines: Optional[list] = None


#: Process-wide set of executable keys already compiled, for compile
#: accounting (jit itself also caches; this mirrors its keying).
_COMPILED_KEYS: set = set()

#: Memoized sharded callables per (group, structure, n_devices).
_EXEC_MEMO: Dict[tuple, object] = {}


def compile_count() -> int:
    """Total sweep executables compiled by this process so far."""
    return len(_COMPILED_KEYS)


def _sharded_executable(group: Tuple[str, ...], nocs: Tuple[str, ...],
                        structure: GeomStructure,
                        n_devices: int, n_apps: int,
                        probe_backend: str = "lax",
                        telemetry: Optional[TelemetryConfig] = None):
    """The jitted, device-sharded, vmapped simulator for one bucket."""
    key = (group, nocs, structure, n_devices, n_apps, probe_backend,
           telemetry)
    fn = _EXEC_MEMO.get(key)
    if fn is None:
        mesh = make_mesh_1d(n_devices, "grid")

        def local_batch(point_arrays):
            return jax.vmap(
                lambda pa: _sim_core(group, nocs, pa, structure,
                                     n_apps, probe_backend,
                                     telemetry))(point_arrays)

        # Pallas backends embed a pallas_call, which has no shard_map
        # replication rule — disable the check for those buckets only
        # (the device-sharded grid axis is fully partitioned anyway, so
        # the check never had anything to prove here).
        smap = (shard_map_norep if probe_backend.startswith("pallas")
                else shard_map)
        fn = jax.jit(smap(local_batch, mesh=mesh,
                          in_specs=P("grid"), out_specs=P("grid")))
        _EXEC_MEMO[key] = fn
    return fn


def _validate_geom(geom: GpuGeometry) -> None:
    if geom.n_cores % geom.cluster_size:
        raise ValueError(
            f"cluster_size={geom.cluster_size} must divide "
            f"n_cores={geom.n_cores}")


def _canonical_group(archs: Iterable[str]) -> Tuple[str, ...]:
    """A dataflow family as an order-independent executable key.

    Members are ordered by registry position, so grids that name the
    same family in different point orders share one compiled executable
    (and one signature memo entry) instead of recompiling per ordering.
    """
    order = {name: i for i, name in enumerate(registered_archs())}
    return tuple(sorted(archs, key=lambda a: order[a]))


def _canonical_noc_group(nocs: Iterable[str]) -> Tuple[str, ...]:
    """NoC stacking family, ordered by registry position (see above)."""
    order = {name: i for i, name in enumerate(registered_nocs())}
    return tuple(sorted(nocs, key=lambda n: order[n]))


def _stack_groups(names: Iterable[str], stack_key_of, canonical
                  ) -> Dict[str, Tuple[str, ...]]:
    """{name: canonical stacked group} over names sharing a stack_key."""
    by_key: Dict[str, List[str]] = {}
    for name in names:
        fam = by_key.setdefault(stack_key_of(name), [])
        if name not in fam:
            fam.append(name)
    out: Dict[str, Tuple[str, ...]] = {}
    for fam in by_key.values():
        group = canonical(fam)
        for name in fam:
            out[name] = group
    return out


#: Memoized abstract round signatures (eval_shape is cheap, not free).
_SIG_MEMO: Dict[tuple, object] = {}


def _signature(group: Tuple[str, ...], arch: str, structure: GeomStructure,
               round_shape: Tuple[int, int],
               insn_shape: Tuple[int, ...] = (), n_apps: int = 1,
               noc_group: Tuple[str, ...] = ("ideal",),
               noc: str = "ideal", probe_backend: str = "lax"):
    key = (group, arch, structure, round_shape, insn_shape, n_apps,
           noc_group, noc, probe_backend)
    if key not in _SIG_MEMO:
        _SIG_MEMO[key] = round_signature(group, arch, structure,
                                         round_shape, insn_shape, n_apps,
                                         noc_group, noc, probe_backend)
    return _SIG_MEMO[key]


class SweepGrid:
    """A cartesian (arch x geometry x noc x trace) grid and its engine.

    ``SweepGrid(archs, geoms, traces, nocs)`` enumerates the full
    product with the trace axis fastest and the arch axis slowest;
    :meth:`from_points` accepts an arbitrary point list instead (the
    engine re-buckets internally either way). :meth:`run` returns the
    per-point :class:`SimResult` list aligned with :attr:`points`, plus
    a :class:`SweepReport`.
    """

    def __init__(self, archs: Sequence[str],
                 geoms: Optional[Sequence[GpuGeometry]] = None,
                 traces: Sequence[Trace] = (),
                 nocs: Sequence[str] = ("ideal",),
                 probe_backends: Sequence[str] = ("lax",)):
        geoms = list(geoms) if geoms is not None else [PAPER_GEOMETRY]
        traces = list(traces)   # tolerate one-shot iterables
        self.points: List[SweepPoint] = [
            SweepPoint(a, g, t, n, pb)
            for a in archs for g in geoms for n in nocs
            for pb in probe_backends for t in traces]
        self._validate()

    @classmethod
    def from_points(cls, points: Iterable[SweepPoint]) -> "SweepGrid":
        grid = cls.__new__(cls)
        grid.points = [SweepPoint(*p) for p in points]
        grid._validate()
        return grid

    def _validate(self) -> None:
        for arch in {p.arch for p in self.points}:
            _check_arch(arch)
        for noc in {p.noc for p in self.points}:
            _check_noc(noc)
        for backend in {p.probe_backend for p in self.points}:
            check_probe_backend(backend)
        seen = set()
        for p in self.points:
            if id(p.geom) not in seen:
                seen.add(id(p.geom))
                _validate_geom(p.geom)
        self._validate_stacking()

    def _noc_group_of(self) -> Dict[str, Tuple[str, ...]]:
        """{noc name: canonical stacked NoC group} over this grid."""
        return _stack_groups({p.noc for p in self.points},
                             lambda n: get_noc(n).stack_key,
                             _canonical_noc_group)

    def _validate_stacking(self) -> None:
        """Reject stack_key families whose members' dataflow diverges.

        Architectures (and NoC models) sharing a ``stack_key`` promise
        an identical round dataflow (same carried state pytree) so the
        engine may compile them into one switch-selected executable. A
        new policy or model that claims an existing family's key but,
        say, threads an extra state array would fail deep inside
        ``lax.switch`` with an opaque shape error — catch it here, per
        (family, geometry structure, round shape) actually swept
        together, with a message that names the offender.
        """
        noc_group_of = self._noc_group_of()
        group_of = _stack_groups(
            dict.fromkeys(p.arch for p in self.points),
            lambda a: get_arch(a).stack_key, _canonical_group)
        for group in {g for g in group_of.values() if len(g) > 1}:
            key = get_arch(group[0]).stack_key
            members = set(group)
            # one representative NoC member per stacked group: whether
            # two archs share a round dataflow cannot depend on which
            # member is selected (the NoC state contribution is
            # group-sized either way), and the NoC-family loop below
            # validates NoC divergence itself — so don't multiply the
            # eval_shape tracings by the NoC axis.
            combos = {(geom_structure(p.geom), p.trace.addr.shape[1:],
                       np.shape(p.trace.insn_per_req), p.trace.n_apps,
                       noc_group_of[p.noc], noc_group_of[p.noc][0],
                       p.probe_backend)
                      for p in self.points if p.arch in members}
            for structure, round_shape, insn_shape, n_apps, ngroup, noc, \
                    backend in combos:
                ref = _signature(group, group[0], structure, round_shape,
                                 insn_shape, n_apps, ngroup, noc, backend)
                for arch in group[1:]:
                    if _signature(group, arch, structure, round_shape,
                                  insn_shape, n_apps, ngroup, noc,
                                  backend) != ref:
                        raise ValueError(
                            f"stack_key {key!r}: architecture {arch!r} "
                            f"does not share {group[0]!r}'s round "
                            "dataflow (state pytrees differ), so they "
                            "cannot stack into one executable; give "
                            f"{arch!r} its own stack_key")
        # NoC families: one fixed architecture per combo, members of the
        # stacked model group must carry identical state pytrees. The
        # groups are exactly the ones run() buckets by, so validation
        # and execution can never disagree on family membership.
        for ngroup in {g for g in noc_group_of.values() if len(g) > 1}:
            key = get_noc(ngroup[0]).stack_key
            members = set(ngroup)
            combos = {(geom_structure(p.geom), p.trace.addr.shape[1:],
                       np.shape(p.trace.insn_per_req), p.trace.n_apps,
                       p.arch, p.probe_backend)
                      for p in self.points if p.noc in members}
            for structure, round_shape, insn_shape, n_apps, arch, backend \
                    in combos:
                agroup = (arch,)
                ref = _signature(agroup, arch, structure, round_shape,
                                 insn_shape, n_apps, ngroup, ngroup[0],
                                 backend)
                for noc in ngroup[1:]:
                    if _signature(agroup, arch, structure, round_shape,
                                  insn_shape, n_apps, ngroup, noc,
                                  backend) != ref:
                        raise ValueError(
                            f"NoC stack_key {key!r}: model {noc!r} does "
                            f"not share {ngroup[0]!r}'s round dataflow "
                            "(carried NoC state pytrees differ), so "
                            "they cannot stack into one executable; "
                            f"give {noc!r} its own stack_key")

    def run(self, n_devices: Optional[int] = None, *,
            telemetry: Optional[TelemetryConfig] = None) -> SweepRun:
        """Sweep every grid point; one sharded dispatch per bucket.

        ``telemetry`` (static, hashable) threads windowed
        observability through every bucket: the returned
        :class:`SweepRun` gains a per-point ``timelines`` list
        (``repro.obs.SimTimeline``, aligned with :attr:`points`) and
        per-point results stay bit-equal to the default run. ``None``
        reuses exactly the pre-telemetry executables.
        """
        t0 = time.perf_counter()
        if telemetry is not None:
            for p in self.points:
                telemetry.window_for(p.trace.addr.shape[0])
        avail = len(jax.devices())
        D = max(1, min(n_devices or avail, avail))

        # Dataflow groups, ordered by first appearance of each arch;
        # NoC stacking groups the same way.
        group_of = _stack_groups(
            dict.fromkeys(p.arch for p in self.points),
            lambda a: get_arch(a).stack_key, _canonical_group)
        noc_group_of = self._noc_group_of()

        # One geometry split per *unique* geometry, not per point: each
        # split commits the GeomScalars leaves to device.
        splits: Dict[GpuGeometry, tuple] = {}

        def split(geom):
            if geom not in splits:
                splits[geom] = split_geometry(geom)
            return splits[geom]

        # Execution buckets: (group, NoC group, structure, trace kind,
        # probe backend) — kind = (addr shape, insn shape, n_apps), so
        # multi-app mixes bucket apart from solo traces but together
        # with each other (no per-mix recompilation), and stacked NoC
        # models ride the same executable as their family. Probe
        # backends bucket apart: they lower different programs.
        buckets: Dict[tuple, List[int]] = {}
        for i, p in enumerate(self.points):
            key = (group_of[p.arch], noc_group_of[p.noc],
                   split(p.geom)[0], trace_kind(p.trace),
                   p.probe_backend)
            buckets.setdefault(key, []).append(i)

        results: List[Optional[SimResult]] = [None] * len(self.points)
        timelines: Optional[list] = (
            [None] * len(self.points) if telemetry is not None else None)
        used_execs: set = set()
        new_compiles = 0
        for (group, noc_group, structure, kind, backend), idxs \
                in buckets.items():
            _, insn_shape, n_apps = kind
            B = len(idxs)
            pad = (-B) % D
            rows = idxs + [idxs[-1]] * pad          # repeat last point
            pts = [self.points[i] for i in rows]
            addr = jnp.asarray(np.stack([p.trace.addr for p in pts]),
                               jnp.int32)
            is_write = jnp.asarray(
                np.stack([p.trace.is_write for p in pts]), bool)
            if insn_shape == ():
                insn = jnp.asarray([p.trace.insn_per_req for p in pts],
                                   jnp.float32)
            else:
                insn = jnp.asarray(
                    np.stack([p.trace.insn_per_req for p in pts]),
                    jnp.float32)
            core_app = jnp.asarray(
                np.stack([p.trace.core_app_ids for p in pts]), jnp.int32)
            scalars = jax.tree.map(
                lambda *leaves: jnp.stack(leaves),
                *[split(p.geom)[1] for p in pts])
            policy_idx = jnp.asarray(
                [group.index(p.arch) for p in pts], jnp.int32)
            noc_idx = jnp.asarray(
                [noc_group.index(p.noc) for p in pts], jnp.int32)
            exec_key = (group, noc_group, structure, kind, backend,
                        B + pad, D, telemetry)
            used_execs.add(exec_key)
            if exec_key not in _COMPILED_KEYS:
                _COMPILED_KEYS.add(exec_key)
                new_compiles += 1
            fn = _sharded_executable(group, noc_group, structure, D,
                                     n_apps, backend, telemetry)
            stats = jax.device_get(
                fn((addr, is_write, insn, core_app, scalars, policy_idx,
                    noc_idx)))
            snaps = stats.pop("timeline", None)
            for b, i in enumerate(idxs):
                p = self.points[i]
                results[i] = _summarize(
                    jax.tree.map(lambda a: a[b], stats), p.trace)
                if telemetry is not None:
                    from repro.obs.timeline import SimTimeline
                    timelines[i] = SimTimeline.from_snapshots(
                        jax.tree.map(lambda a: a[b], snaps), telemetry,
                        rounds=p.trace.addr.shape[0],
                        meta={"arch": p.arch, "noc": p.noc,
                              "n_apps": p.trace.n_apps,
                              "n_cores": p.trace.n_cores,
                              "probe_backend": p.probe_backend})

        report = SweepReport(
            n_points=len(self.points),
            n_executables=len(used_execs),
            n_compiles=new_compiles,
            n_devices=D,
            wall_s=time.perf_counter() - t0,
        )
        return SweepRun(results=results, report=report,  # type: ignore
                        timelines=timelines)
