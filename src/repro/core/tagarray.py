"""Functional (JAX) set-associative tag arrays with timestamp LRU.

State is a dict of arrays so it threads through ``lax.scan`` carries:

    tags : (n_arrays, n_sets, n_ways) int32   line address stored per way
    last : (n_arrays, n_sets, n_ways) int32   last-touch timestamp (LRU)
    valid: (n_arrays, n_sets, n_ways) bool
    dirty: (n_arrays, n_sets, n_ways) bool

All operations are batched over a request vector. ``probe_many`` is the
pure-jnp form of the paper's *aggregated tag array*: one request compared
against the tag arrays of every cache in its cluster in parallel — the
same computation `repro.kernels.ata_tag_probe` implements as a Pallas TPU
kernel (a test asserts they agree).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

TagState = Dict[str, jnp.ndarray]


def init_tag_state(n_arrays: int, n_sets: int, n_ways: int) -> TagState:
    shape = (n_arrays, n_sets, n_ways)
    return {
        "tags": jnp.zeros(shape, jnp.int32),
        "last": jnp.full(shape, -1, jnp.int32),
        "valid": jnp.zeros(shape, bool),
        "dirty": jnp.zeros(shape, bool),
    }


def probe(state: TagState, array_idx: jnp.ndarray, set_idx: jnp.ndarray,
          addr: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Look up one (array, set) per request.

    Returns (hit, way, dirty_hit); way is the hit way or the LRU victim.
    """
    tags = state["tags"][array_idx, set_idx]      # (R, W)
    valid = state["valid"][array_idx, set_idx]
    last = state["last"][array_idx, set_idx]
    match = (tags == addr[:, None]) & valid
    hit = match.any(axis=-1)
    hit_way = jnp.argmax(match, axis=-1)
    victim = jnp.argmin(jnp.where(valid, last, jnp.iinfo(jnp.int32).min),
                        axis=-1)
    way = jnp.where(hit, hit_way, victim)
    dirty_hit = (match & state["dirty"][array_idx, set_idx]).any(axis=-1)
    return hit, way, dirty_hit


def probe_many(state: TagState, arrays: jnp.ndarray, set_idx: jnp.ndarray,
               addr: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Aggregated-tag-array probe: each request vs a *group* of arrays.

    arrays : (R, G) int32 — the G tag arrays (cluster caches) per request
    Returns (hits (R, G), ways (R, G), dirty (R, G)).
    """
    tags = state["tags"][arrays, set_idx[:, None]]    # (R, G, W)
    valid = state["valid"][arrays, set_idx[:, None]]
    match = (tags == addr[:, None, None]) & valid
    hits = match.any(axis=-1)
    ways = jnp.argmax(match, axis=-1)
    dirty = (match & state["dirty"][arrays, set_idx[:, None]]).any(axis=-1)
    return hits, ways, dirty


def touch(state: TagState, array_idx, set_idx, way, now,
          mask, *, set_dirty=None) -> TagState:
    """Refresh LRU timestamp (and optionally dirty) for masked requests."""
    a = jnp.where(mask, array_idx, 0)
    s = jnp.where(mask, set_idx, 0)
    w = jnp.where(mask, way, 0)
    last = state["last"].at[a, s, w].max(jnp.where(mask, now, -1))
    out = dict(state, last=last)
    if set_dirty is not None:
        out["dirty"] = state["dirty"].at[a, s, w].set(
            jnp.where(mask & set_dirty, True, state["dirty"][a, s, w]))
    return out


def fill(state: TagState, array_idx, set_idx, way, addr, now,
         mask, *, dirty=None) -> Tuple[TagState, jnp.ndarray]:
    """Install lines for masked requests; returns (state, evicted_dirty).

    Duplicate (array,set,way) targets resolve last-writer-wins, matching a
    single-ported fill path. ``evicted_dirty`` flags write-back traffic.
    """
    a = jnp.where(mask, array_idx, 0)
    s = jnp.where(mask, set_idx, 0)
    w = jnp.where(mask, way, 0)
    old_valid = state["valid"][a, s, w]
    old_dirty = state["dirty"][a, s, w]
    evicted_dirty = mask & old_valid & old_dirty

    tags = state["tags"].at[a, s, w].set(
        jnp.where(mask, addr, state["tags"][a, s, w]))
    valid = state["valid"].at[a, s, w].set(
        jnp.where(mask, True, old_valid))
    last = state["last"].at[a, s, w].max(jnp.where(mask, now, -1))
    new_dirty = jnp.where(mask, dirty if dirty is not None else False,
                          old_dirty)
    dirty_arr = state["dirty"].at[a, s, w].set(new_dirty)
    return {"tags": tags, "last": last, "valid": valid,
            "dirty": dirty_arr}, evicted_dirty
