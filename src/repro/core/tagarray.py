"""Functional (JAX) set-associative tag arrays with pluggable replacement.

State is a dict of arrays so it threads through ``lax.scan`` carries:

    tags : (n_arrays, n_sets, n_ways) int32   line address stored per way
    last : (n_arrays, n_sets, n_ways) int32   last-touch timestamp (LRU)
    born : (n_arrays, n_sets, n_ways) int32   install timestamp (FIFO)
    valid: (n_arrays, n_sets, n_ways) bool
    dirty: (n_arrays, n_sets, n_ways) bool

plus two policy-zoo *state extensions*, zero-sized unless requested at
``init_tag_state`` time (the keys are always present, so every TagState
shares one pytree structure and stacked sweep executables line up):

    vtags : (n_arrays, victim_ways) int32   victim tag buffer per array
    vvalid: (n_arrays, victim_ways) bool    (fully associative, FIFO)
    vborn : (n_arrays, victim_ways) int32   install timestamp per entry
    thrash: (thrash_lanes,) int32           per-lane thrash counters

Zero-sized extensions are exact no-ops: ``victim_probe`` returns all
misses and ``victim_insert``/``victim_invalidate`` return the state
unchanged, so architectures that ignore the extensions are bit-exact
with and without them (a hypothesis test asserts this).

Victim selection is controlled by :class:`ReplacementPolicy` (LRU, FIFO,
or deterministic pseudo-random), threaded through ``probe``/``fill`` so
architecture policies in ``repro.core.arch`` can run the same cache
organization under different replacement schemes.

All operations are batched over a request vector. ``probe_many`` is the
pure-jnp form of the paper's *aggregated tag array*: one request compared
against the tag arrays of every cache in its cluster in parallel — the
same computation `repro.kernels.ata_tag_probe` implements as a Pallas TPU
kernel (a test asserts they agree).

Scatter-mask convention: mutating ops (``touch``/``fill``) route
masked-*out* requests to an out-of-bounds array index and scatter with
``mode="drop"``, so they touch no entry at all. (They must *not* be
parked at a valid index like ``(0, 0, 0)`` and scatter their old value
back: XLA resolves duplicate scatter indices last-writer-wins, so a
parked no-op landing after a genuine update to array 0 / set 0 / way 0
would revert it — e.g. a core-0 fill undone, a dirty bit lost, a missed
write-back.) Within the masked-*in* requests, duplicate
(array, set, way) targets still resolve last-writer-wins, matching a
single-ported fill path.
"""
from __future__ import annotations

import enum
from typing import Dict, Tuple

import jax.numpy as jnp

TagState = Dict[str, jnp.ndarray]


class ReplacementPolicy(enum.Enum):
    """Victim-selection scheme for ``probe``/``fill``.

    LRU    — least-recently-*touched* way (timestamp ``last``)
    FIFO   — oldest-*installed* way (timestamp ``born``); touches do not
             refresh position
    RANDOM — deterministic hash of the line address over the valid ways
             (invalid ways are still preferred, as in real designs)
    """
    LRU = "lru"
    FIFO = "fifo"
    RANDOM = "random"


def init_tag_state(n_arrays: int, n_sets: int, n_ways: int, *,
                   victim_ways: int = 0, thrash_lanes: int = 0) -> TagState:
    shape = (n_arrays, n_sets, n_ways)
    return {
        "tags": jnp.zeros(shape, jnp.int32),
        "last": jnp.full(shape, -1, jnp.int32),
        "born": jnp.full(shape, -1, jnp.int32),
        "valid": jnp.zeros(shape, bool),
        "dirty": jnp.zeros(shape, bool),
        # policy-zoo extensions — zero-sized unless a policy asks for
        # them, so the pytree structure is uniform across architectures.
        "vtags": jnp.zeros((n_arrays, victim_ways), jnp.int32),
        "vvalid": jnp.zeros((n_arrays, victim_ways), bool),
        "vborn": jnp.full((n_arrays, victim_ways), -1, jnp.int32),
        "thrash": jnp.zeros((thrash_lanes,), jnp.int32),
    }


def _select_victim(state: TagState, array_idx, set_idx, addr,
                   valid: jnp.ndarray,
                   policy: ReplacementPolicy) -> jnp.ndarray:
    """Victim way per request; invalid ways always win first."""
    int_min = jnp.iinfo(jnp.int32).min
    if policy is ReplacementPolicy.LRU:
        last = state["last"][array_idx, set_idx]
        return jnp.argmin(jnp.where(valid, last, int_min), axis=-1)
    if policy is ReplacementPolicy.FIFO:
        born = state["born"][array_idx, set_idx]
        return jnp.argmin(jnp.where(valid, born, int_min), axis=-1)
    if policy is ReplacementPolicy.RANDOM:
        n_ways = state["tags"].shape[-1]
        # Knuth multiplicative hash of the line address: deterministic,
        # trace-reproducible, uniform over ways.
        h = addr.astype(jnp.uint32) * jnp.uint32(2654435761)
        h = (h >> jnp.uint32(16)) ^ h
        rand_way = (h % jnp.uint32(n_ways)).astype(jnp.int32)
        first_invalid = jnp.argmin(valid, axis=-1).astype(jnp.int32)
        return jnp.where(valid.all(axis=-1), rand_way, first_invalid)
    raise ValueError(f"unknown replacement policy {policy!r}")


def probe(state: TagState, array_idx: jnp.ndarray, set_idx: jnp.ndarray,
          addr: jnp.ndarray,
          policy: ReplacementPolicy = ReplacementPolicy.LRU,
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Look up one (array, set) per request.

    Returns (hit, way, dirty_hit); way is the hit way or the victim the
    replacement ``policy`` selects.
    """
    tags = state["tags"][array_idx, set_idx]      # (R, W)
    valid = state["valid"][array_idx, set_idx]
    match = (tags == addr[:, None]) & valid
    hit = match.any(axis=-1)
    hit_way = jnp.argmax(match, axis=-1)
    victim = _select_victim(state, array_idx, set_idx, addr, valid, policy)
    way = jnp.where(hit, hit_way, victim)
    dirty_hit = (match & state["dirty"][array_idx, set_idx]).any(axis=-1)
    return hit, way, dirty_hit


def probe_many(state: TagState, arrays: jnp.ndarray, set_idx: jnp.ndarray,
               addr: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Aggregated-tag-array probe: each request vs a *group* of arrays.

    arrays : (R, G) int32 — the G tag arrays (cluster caches) per request
    Returns (hits (R, G), ways (R, G), dirty (R, G)).
    """
    tags = state["tags"][arrays, set_idx[:, None]]    # (R, G, W)
    valid = state["valid"][arrays, set_idx[:, None]]
    match = (tags == addr[:, None, None]) & valid
    hits = match.any(axis=-1)
    ways = jnp.argmax(match, axis=-1)
    dirty = (match & state["dirty"][arrays, set_idx[:, None]]).any(axis=-1)
    return hits, ways, dirty


def _drop_unmasked(state: TagState, array_idx, mask) -> jnp.ndarray:
    """Scatter array index that routes masked-out requests out of bounds.

    Combined with ``mode="drop"`` the scatter then skips them entirely —
    see the scatter-mask convention in the module docstring.
    """
    return jnp.where(mask, array_idx, state["tags"].shape[0])


def touch(state: TagState, array_idx, set_idx, way, now,
          mask, *, set_dirty=None) -> TagState:
    """Refresh LRU timestamp (and optionally dirty) for masked requests."""
    a = _drop_unmasked(state, array_idx, mask)
    last = state["last"].at[a, set_idx, way].max(now, mode="drop")
    out = dict(state, last=last)
    if set_dirty is not None:
        ad = _drop_unmasked(state, array_idx, mask & set_dirty)
        out["dirty"] = state["dirty"].at[ad, set_idx, way].set(
            True, mode="drop")
    return out


def fill(state: TagState, array_idx, set_idx, way, addr, now,
         mask, *, dirty=None) -> Tuple[TagState, jnp.ndarray]:
    """Install lines for masked requests; returns (state, evicted_dirty).

    Masked-out requests are dropped (see the scatter-mask convention in
    the module docstring); within the masked-in set, duplicate
    (array,set,way) targets resolve last-writer-wins, matching a
    single-ported fill path. ``evicted_dirty`` flags write-back traffic.
    """
    a = _drop_unmasked(state, array_idx, mask)
    # Reads use the caller's (always in-bounds) indices; the results are
    # masked, so masked-out lanes never contribute.
    old_valid = state["valid"][array_idx, set_idx, way]
    old_dirty = state["dirty"][array_idx, set_idx, way]
    evicted_dirty = mask & old_valid & old_dirty

    tags = state["tags"].at[a, set_idx, way].set(addr, mode="drop")
    valid = state["valid"].at[a, set_idx, way].set(True, mode="drop")
    last = state["last"].at[a, set_idx, way].max(now, mode="drop")
    born = state["born"].at[a, set_idx, way].set(now, mode="drop")
    new_dirty = dirty if dirty is not None else jnp.zeros_like(mask)
    dirty_arr = state["dirty"].at[a, set_idx, way].set(new_dirty,
                                                       mode="drop")
    # dict(state, ...) so zoo state extensions (victim buffer, thrash
    # counters) ride through untouched.
    return dict(state, tags=tags, last=last, born=born, valid=valid,
                dirty=dirty_arr), evicted_dirty


def dead_victim(state: TagState, array_idx: jnp.ndarray,
                set_idx: jnp.ndarray, addr: jnp.ndarray,
                policy: ReplacementPolicy = ReplacementPolicy.LRU,
                ) -> jnp.ndarray:
    """Predict whether a fill for ``addr`` would evict a *dead* line.

    Dead = the replacement victim the ``policy`` would select is valid
    but was never re-touched after its own install (``last == born``) —
    the set is absorbing streaming traffic. Shared detector of the
    CIAO-style policies (``ata_bypass`` fill bypass, ``ciao`` thrash
    counters).
    """
    _, victim, _ = probe(state, array_idx, set_idx, addr, policy=policy)
    last = state["last"][array_idx, set_idx, victim]
    born = state["born"][array_idx, set_idx, victim]
    valid = state["valid"][array_idx, set_idx, victim]
    return valid & (last == born)


# ---------------------------------------------------------------------------
# Victim tag buffer (policy-zoo extension; see module docstring)
# ---------------------------------------------------------------------------
def victim_ways(state: TagState) -> int:
    """Entries per array in the victim tag buffer (0 = disabled)."""
    return state["vtags"].shape[-1]


def victim_probe(state: TagState, array_idx: jnp.ndarray,
                 addr: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fully-associative lookup in each request's victim buffer.

    Returns (hit, slot). A zero-sized buffer never hits.
    """
    R = array_idx.shape[0]
    if victim_ways(state) == 0:
        return jnp.zeros((R,), bool), jnp.zeros((R,), jnp.int32)
    vtags = state["vtags"][array_idx]            # (R, V)
    vvalid = state["vvalid"][array_idx]
    match = (vtags == addr[:, None]) & vvalid
    hit = match.any(axis=-1)
    slot = jnp.argmax(match, axis=-1).astype(jnp.int32)
    return hit, slot


def victim_invalidate(state: TagState, array_idx: jnp.ndarray,
                      slot: jnp.ndarray, mask: jnp.ndarray) -> TagState:
    """Drop masked requests' victim entries (e.g. on promote back to L1)."""
    if victim_ways(state) == 0:
        return state
    a = jnp.where(mask, array_idx, state["vtags"].shape[0])
    return dict(state, vvalid=state["vvalid"].at[a, slot].set(
        False, mode="drop"))


def victim_insert(state: TagState, array_idx: jnp.ndarray,
                  addr: jnp.ndarray, now, mask: jnp.ndarray) -> TagState:
    """FIFO-install masked requests' tags into their victim buffers.

    Invalid slots win first, then the oldest install. Duplicate
    (array, slot) targets resolve last-writer-wins, like ``fill`` — a
    round that evicts several lines from one cache keeps only the last
    (the buffer has one fill port).
    """
    if victim_ways(state) == 0:
        return state
    int_min = jnp.iinfo(jnp.int32).min
    vvalid = state["vvalid"][array_idx]          # (R, V)
    vborn = state["vborn"][array_idx]
    slot = jnp.argmin(jnp.where(vvalid, vborn, int_min),
                      axis=-1).astype(jnp.int32)
    a = jnp.where(mask, array_idx, state["vtags"].shape[0])
    return dict(
        state,
        vtags=state["vtags"].at[a, slot].set(addr, mode="drop"),
        vvalid=state["vvalid"].at[a, slot].set(True, mode="drop"),
        vborn=state["vborn"].at[a, slot].set(now, mode="drop"))
