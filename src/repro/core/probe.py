"""Selectable probe backends for the ATA round loop.

The aggregated-tag-array policies (``repro.core.arch.ata`` and its
family) spend their round in one computation: probe the request batch
against every cluster tag array, pick the per-request winner (self hit,
else first hitting peer), and arbitrate the known remote hits at their
serving caches' data ports. :func:`fused_probe_rank` is that whole
chain as one op with interchangeable lowerings — the **probe backend**,
a *static* axis of the simulator (backends differ structurally, so each
compiles its own executable; contrast the *traced* NoC index, which
switches between same-dataflow models inside one executable):

``lax``
    The default: a fused pure-XLA pass. One ``probe_many`` gather
    feeds hit selection, peer pick, and
    :func:`repro.core.contention.group_rank` arbitration directly.
    Crucially it does *not* run the replacement-victim probe of the
    historical chain: the victim way was only ever consumed by
    ``tagarray.touch`` lanes that the touch itself drops (masked-out
    requests are routed out of bounds), but XLA cannot dead-code it
    because the scatter consumes the way operand for every lane — so
    dropping it here is bit-exact *and* a real rounds/sec win
    (``benchmarks/sim_speed.py`` measures it).
``lax_unfused``
    The historical probe→``group_rank``→arbitrate chain, victim probe
    included, kept as the measured pre-fusion baseline and as the
    executable definition of what the fused paths must reproduce
    bit-exactly.
``pallas``
    The fused Pallas TPU kernel (``repro.kernels.ata_probe_rank``):
    the same chain in one VMEM-resident pass per request tile,
    compiled by Mosaic. TPU only.
``pallas_interpret``
    The same kernel body interpreted on CPU — the exact-equivalence
    artifact tier-1 tests pin against ``lax``.

All four return identical integers/booleans (tier-1 tested), so every
committed golden is backend-invariant.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core import tagarray
from repro.core.contention import group_rank
from repro.core.tagarray import ReplacementPolicy

#: The static backend axis, in canonical order.
PROBE_BACKENDS: Tuple[str, ...] = ("lax", "lax_unfused", "pallas",
                                   "pallas_interpret")
DEFAULT_PROBE_BACKEND = "lax"


def check_probe_backend(backend: str) -> None:
    if backend not in PROBE_BACKENDS:
        raise ValueError(
            f"probe_backend must be one of {PROBE_BACKENDS}, "
            f"got {backend!r}")


class ProbeRank(NamedTuple):
    """The fused chain's outputs, all (R,).

    ``touch_way`` is what the policy hands to ``tagarray.touch`` for
    its local-hit refresh: the self-array hit way where ``local_hit``
    (elsewhere the touch drops the lane, so the value is dead — the
    ``lax_unfused`` backend fills in the historical replacement-victim
    way there, the fused backends do not). ``prank``/``psize`` are the
    queue position and group size at the serving cache's data port,
    exactly ``group_rank(src_cache, remote_ok, n_cores)``.
    """
    local_hit: jnp.ndarray   # bool — hit in the requester's own array
    touch_way: jnp.ndarray   # int32 — way to LRU-touch where local_hit
    remote_ok: jnp.ndarray   # bool — serviceable known remote hit
    src_cache: jnp.ndarray   # int32 — serving peer cache id
    prank: jnp.ndarray       # int32 — position at the serving port
    psize: jnp.ndarray       # int32 — contention group size


def _lax_path(geom, l1: tagarray.TagState, reqs, pre_served,
              replacement: ReplacementPolicy, fused: bool) -> ProbeRank:
    addr, set_idx = reqs.addr, reqs.set_idx
    hits, ways, dirt = tagarray.probe_many(l1, reqs.peers, set_idx, addr)
    is_self = (jnp.arange(geom.cluster_size)[None, :]
               == reqs.self_slot[:, None])
    local_hit = (hits & is_self).any(axis=-1)
    hit_way = jnp.take_along_axis(ways, reqs.self_slot[:, None],
                                  axis=1)[:, 0]
    if fused:
        touch_way = hit_way
    else:
        # historical chain: the replacement-victim probe whose result is
        # dead where ~local_hit but un-DCE-able behind the touch scatter
        touch_way = jnp.where(
            local_hit, hit_way,
            tagarray.probe(l1, reqs.core, set_idx, addr,
                           policy=replacement)[1])
    rmask = hits & ~is_self
    any_remote = rmask.any(axis=-1)
    src_slot = jnp.argmax(rmask, axis=-1)
    src_cache = reqs.cluster * geom.cluster_size + src_slot
    src_dirty = jnp.take_along_axis(dirt, src_slot[:, None], axis=1)[:, 0]
    # writes are local-only (paper coherence rule); dirty remote copies
    # divert the read to L2; prefilter-served reads skip the port.
    remote_ok = ((~reqs.is_write) & (~local_hit) & any_remote
                 & (~src_dirty))
    if pre_served is not None:
        remote_ok = remote_ok & ~pre_served
    prank, psize = group_rank(src_cache, remote_ok, geom.n_cores)
    return ProbeRank(local_hit, touch_way, remote_ok, src_cache,
                     prank, psize)


def _pallas_path(geom, l1: tagarray.TagState, reqs, pre_served,
                 interpret: Optional[bool]) -> ProbeRank:
    from repro.kernels.ata_probe_rank import ata_probe_rank
    deny = reqs.is_write
    if pre_served is not None:
        deny = deny | pre_served
    cbase = reqs.cluster * geom.cluster_size
    local_hit, way, remote_ok, src, prank, psize = ata_probe_rank(
        reqs.set_idx, reqs.addr, reqs.core, cbase, deny,
        l1["tags"], l1["valid"], l1["dirty"],
        cluster_size=geom.cluster_size, interpret=interpret)
    return ProbeRank(local_hit, way, remote_ok, src, prank, psize)


def fused_probe_rank(geom, l1: tagarray.TagState, reqs, *,
                     pre_served: Optional[jnp.ndarray] = None,
                     replacement: ReplacementPolicy = ReplacementPolicy.LRU,
                     backend: str = DEFAULT_PROBE_BACKEND) -> ProbeRank:
    """Probe + winner pick + port arbitration under one backend.

    ``pre_served`` (optional (R,) bool) marks requests a victim
    structure will serve locally; they are excluded from the remote
    contention group (``remote_ok & ~pre_served`` — equal to the
    historical ``& ~vserved`` since ``remote_ok`` already excludes
    writes and local hits). ``replacement`` only matters to
    ``lax_unfused``, which reproduces the historical victim probe.
    """
    check_probe_backend(backend)
    if backend == "lax":
        return _lax_path(geom, l1, reqs, pre_served, replacement, True)
    if backend == "lax_unfused":
        return _lax_path(geom, l1, reqs, pre_served, replacement, False)
    return _pallas_path(geom, l1, reqs, pre_served,
                        interpret=(backend == "pallas_interpret"))
