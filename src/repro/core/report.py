"""Sensitivity reports over :class:`~repro.core.sweep.SweepGrid` runs.

:func:`run_sensitivity` sweeps the contention-policy zoo over widened
geometry axes around the paper's Table-II point —

    l1_ways : L1 associativity (structural: regroups per shape)
    noc_bw  : probe-network bandwidth (traced scalar)
    hide    : warp-level latency-hiding depth (traced scalar)

— every (arch x knob-value x kernel) point through *one* grid run, and
aggregates per (arch x geometry) cell into a machine-readable report
dict: IPC, L1 hit rate, remote-probe rate, NoC flits, plus the grid's
:class:`~repro.core.sweep.SweepReport` accounting. :func:`write_report`
serializes it as ``BENCH_sensitivity.json`` with a markdown sensitivity
table alongside; ``benchmarks.run --report-json`` wires it into the
benchmark driver.

The report doubles as CI's benchmark-regression gate:
:func:`compare_reports` diffs a fresh report against a committed
baseline and flags per-cell IPC drift beyond a tolerance or
executable-count growth (``scripts/check_bench_regression.py`` is the
thin CLI; the sharded-sweep-smoke workflow job runs it on every PR).

Schema history (``SCHEMA_VERSION``):

  1  solo policy-zoo cells only (``config``/``sweep``/``cells``)
  2  adds the multi-tenant ``mix`` section (its own config/sweep/cells
     from :func:`run_mix_sensitivity`); solo sections unchanged
  3  adds the interconnect-topology ``noc`` section
     (:func:`run_noc_sensitivity`: the zoo x {ideal, crossbar, ring} x
     ``noc_bw``); earlier sections unchanged

The gate is *forward-compatible*: a candidate at a newer schema is
compared against an older baseline on the sections the baseline
carries (solo cells, solo executable count, baseline config keys), so
committing a new report section never breaks the gate against an old
baseline — only drift in shared cells does.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.geometry import GpuGeometry, PAPER_GEOMETRY
from repro.core.metrics import (AppResult, MixRun, app_traces,
                                grid_app_results, kernel_range, run_mixes)
from repro.core.noc import PAPER_NOCS
from repro.core.sweep import SweepGrid, SweepPoint
from repro.core.trace import WorkloadMix

SCHEMA_VERSION = 3

#: The zoo comparison set: the paper's poles, the probe-broadcast
#: baseline (the only ``noc_bw`` consumer), and both new policies.
SENSITIVITY_ARCHS: Tuple[str, ...] = ("private", "remote", "ata", "ciao",
                                      "victim")

#: Widened geometry axes (ROADMAP follow-on); middle value = paper point.
SENSITIVITY_KNOBS: Dict[str, Tuple] = {
    "l1_ways": (32, 64, 128),
    "noc_bw": (8.0, 16.0, 32.0),
    "hide": (5.0, 10.0, 20.0),
}

#: Metrics reported per (arch x geometry) cell.
CELL_METRICS = ("ipc", "l1_hit_rate", "remote_hit_rate", "noc_flits",
                "l1_latency")

#: The full zoo for the multi-tenant fairness sweep.
MIX_ARCHS: Tuple[str, ...] = ("private", "remote", "decoupled", "ata",
                              "ciao", "victim")

#: ``noc_bw`` values the topology section sweeps (paper point = 16).
NOC_BW_VALUES: Tuple[float, ...] = (4.0, 8.0, 16.0, 32.0)

#: Metrics reported per (arch x noc x noc_bw) topology cell.
#: `noc_flits_injected` is the traffic the modeled interconnect
#: actually routes (probe + remote-data flits), not the legacy
#: memory-side `noc_flits` total.
NOC_CELL_METRICS = ("ipc", "l1_hit_rate", "remote_hit_rate",
                    "noc_flits_injected", "noc_mean_queue_delay",
                    "noc_max_link_util")

#: Locality mixes: high x high, high x low, low x low pairs, plus one
#: 3-app point (hi x hi x lo — ``WorkloadMix`` composes any app count;
#: weighted-speedup ideal = n_apps, so 3-app cells top out at 3.0).
MIX_PAIRINGS: Tuple[Tuple[str, ...], ...] = (
    ("cfd", "b+tree"), ("cfd", "HS3D"), ("HS3D", "sradv1"),
    ("cfd", "b+tree", "HS3D"))


def mix_grid_run(pairings: Sequence[Tuple[str, ...]] = MIX_PAIRINGS,
                 archs: Sequence[str] = MIX_ARCHS,
                 rounds: Optional[int] = None,
                 geom: GpuGeometry = PAPER_GEOMETRY,
                 n_devices: Optional[int] = None) -> MixRun:
    """The canonical (pairing x zoo-arch) fairness grid run.

    One :func:`repro.core.metrics.run_mixes` call over
    ``WorkloadMix(apps=pair)`` per pairing — shared by
    :func:`run_mix_sensitivity` and ``benchmarks/fig_mix_fairness``
    (``benchmarks.run --report-json`` computes it once and feeds both).
    """
    mixes = [WorkloadMix(apps=tuple(p)) for p in pairings]
    return run_mixes(mixes, tuple(archs), geom=geom, rounds=rounds,
                     n_devices=n_devices)


def run_mix_sensitivity(pairings: Sequence[Tuple[str, ...]] = MIX_PAIRINGS,
                        archs: Sequence[str] = MIX_ARCHS,
                        rounds: Optional[int] = None,
                        geom: GpuGeometry = PAPER_GEOMETRY,
                        n_devices: Optional[int] = None,
                        mix_run: Optional[MixRun] = None) -> dict:
    """The multi-tenant ``mix`` report section: fairness of the zoo.

    One :func:`repro.core.metrics.run_mixes` grid run over
    (pairing x arch), reporting weighted speedup, unfairness, mix IPC,
    and per-app IPC / L1 hit rate per cell, plus the grid's own
    executable accounting (kept separate from the solo sweep's so the
    solo regression gate is unaffected by this section existing).
    ``mix_run`` reuses an existing :func:`mix_grid_run` result — it
    must have been produced from the same pairings/archs/rounds.
    """
    archs = tuple(archs)
    run = mix_run if mix_run is not None else mix_grid_run(
        pairings, archs, rounds=rounds, geom=geom, n_devices=n_devices)
    cells = []
    for mid, per_arch in run.results.items():
        for arch, mr in per_arch.items():
            cells.append({
                "mix": mid, "arch": arch,
                "weighted_speedup": float(mr.weighted_speedup),
                "unfairness": float(mr.unfairness),
                "ipc": float(mr.shared.ipc),
                "per_app_ipc": [float(x) for x in mr.per_app_ipc],
                "per_app_l1_hit_rate": [float(x)
                                        for x in mr.per_app_l1_hit_rate],
            })
    return {
        "config": {
            "pairings": [list(p) for p in pairings],
            "archs": list(archs),
            "rounds": rounds,
        },
        "sweep": {
            "n_points": run.report.n_points,
            "n_executables": run.report.n_executables,
            "n_compiles": run.report.n_compiles,
            "n_devices": run.report.n_devices,
            "wall_s": round(run.report.wall_s, 3),
        },
        "cells": cells,
    }


def run_noc_sensitivity(app: str = "HS3D",
                        archs: Sequence[str] = SENSITIVITY_ARCHS,
                        nocs: Sequence[str] = PAPER_NOCS,
                        noc_bw: Sequence[float] = NOC_BW_VALUES,
                        kernels_per_app: Optional[int] = 1,
                        rounds: Optional[int] = None,
                        geom: GpuGeometry = PAPER_GEOMETRY,
                        n_devices: Optional[int] = None) -> dict:
    """The interconnect-topology ``noc`` report section.

    One :class:`~repro.core.sweep.SweepGrid` run over
    (arch x noc model x ``noc_bw``) — the paper's contention-
    sensitivity story per topology: how much of each policy's win
    survives a crossbar with real backpressure or a ring with
    hop-distance latency, as the probe-network bandwidth shrinks. The
    NoC axis stacks (all built-ins share one model family), so the
    whole section compiles one executable per architecture family.
    Cells carry the solo metrics plus the interconnect block's queue
    delay and hotspot link utilization; the section keeps its own
    ``sweep`` accounting so the solo regression gate is unaffected.

    Deliberate trade-off: the ``ideal`` rows at ``noc_bw`` values the
    solo section also sweeps re-simulate those points rather than
    borrowing the solo results. The redundant work is only the device
    time of a handful of cells inside a stacked executable the
    crossbar/ring rows need compiled anyway, and it keeps the two
    sections' sweep accounting (and therefore the regression gate's
    per-section executable budgets) fully independent.
    """
    archs = tuple(archs)
    nocs = tuple(nocs)
    traces = app_traces(app, geom, kernel_range(app, kernels_per_app),
                       rounds=rounds)
    geoms = [dataclasses.replace(geom, noc_bw=v) for v in noc_bw]
    grid = SweepGrid(archs, geoms, traces, nocs=nocs)
    run = grid.run(n_devices=n_devices)
    agg = grid_app_results(grid, run.results, app)
    cells = []
    for arch in archs:
        for v, g in zip(noc_bw, geoms):
            for noc in nocs:
                cell = {"arch": arch, "noc": noc, "noc_bw": v}
                for metric in NOC_CELL_METRICS:
                    cell[metric] = float(getattr(agg[(arch, g, noc)],
                                                 metric))
                cells.append(cell)
    return {
        "config": {
            "app": app,
            "archs": list(archs),
            "nocs": list(nocs),
            "noc_bw": list(noc_bw),
            "kernels_per_app": kernels_per_app,
            "rounds": rounds,
        },
        "sweep": {
            "n_points": run.report.n_points,
            "n_executables": run.report.n_executables,
            "n_compiles": run.report.n_compiles,
            "n_devices": run.report.n_devices,
            "wall_s": round(run.report.wall_s, 3),
        },
        "cells": cells,
    }


def run_sensitivity(app: str = "HS3D",
                    archs: Sequence[str] = SENSITIVITY_ARCHS,
                    knobs: Optional[Dict[str, Tuple]] = None,
                    kernels_per_app: Optional[int] = 1,
                    rounds: Optional[int] = None,
                    geom: GpuGeometry = PAPER_GEOMETRY,
                    n_devices: Optional[int] = None,
                    mix_pairings: Optional[Sequence[Tuple[str, ...]]]
                    = None,
                    mix_run: Optional[MixRun] = None,
                    noc_models: Optional[Sequence[str]] = None) -> dict:
    """One grid run over (arch x knob-value x kernel); report dict out.

    ``mix_pairings`` (e.g. ``MIX_PAIRINGS``) adds the multi-tenant
    ``mix`` section (schema 2; ``benchmarks.run --report-json`` passes
    it, with ``mix_run`` reusing the grid run the fairness figure
    already paid for); ``noc_models`` (e.g. ``PAPER_NOCS``) adds the
    interconnect-topology ``noc`` section (schema 3,
    :func:`run_noc_sensitivity`) — the solo sections are unchanged
    either way and keep their own ``sweep`` accounting, so a schema-1
    baseline still gates them.
    """
    knobs = dict(SENSITIVITY_KNOBS if knobs is None else knobs)
    archs = tuple(archs)
    traces = app_traces(app, geom, kernel_range(app, kernels_per_app),
                        rounds=rounds)
    # Each knob lists the paper point among its values, so several cells
    # share one (arch, geometry): simulate each unique pair once and fan
    # the result out to every cell that references it.
    labels: List[Tuple[str, object, str, GpuGeometry]] = []
    start: Dict[Tuple[str, GpuGeometry], int] = {}
    points: List[SweepPoint] = []
    for knob, values in knobs.items():
        for value in values:
            g = dataclasses.replace(geom, **{knob: value})
            for arch in archs:
                labels.append((knob, value, arch, g))
                if (arch, g) not in start:
                    start[(arch, g)] = len(points)
                    points.extend(SweepPoint(arch, g, t) for t in traces)
    grid = SweepGrid.from_points(points)
    run = grid.run(n_devices=n_devices)

    cells = []
    per_cell = len(traces)
    for knob, value, arch, g in labels:
        lo = start[(arch, g)]
        agg = AppResult(app, arch, run.results[lo:lo + per_cell])
        cell = {"knob": knob, "value": value, "arch": arch}
        for metric in CELL_METRICS:
            cell[metric] = float(getattr(agg, metric))
        cells.append(cell)

    report = {
        # The schema tag is the highest *contiguous* coverage level
        # actually present (sections themselves are gated by presence):
        # schema 3 requires both mix and noc sections, so a noc-only
        # report cannot claim 3 while silently dropping mix coverage —
        # nor spuriously reject a schema-2 candidate that carries it.
        "schema": (3 if (mix_pairings and noc_models)
                   else 2 if mix_pairings else 1),
        "config": {
            "app": app,
            "archs": list(archs),
            "knobs": {k: list(v) for k, v in knobs.items()},
            "kernels_per_app": kernels_per_app,
            "rounds": rounds,
        },
        "sweep": {
            "n_points": run.report.n_points,
            "n_executables": run.report.n_executables,
            "n_compiles": run.report.n_compiles,
            "n_devices": run.report.n_devices,
            "wall_s": round(run.report.wall_s, 3),
        },
        "cells": cells,
    }
    if mix_pairings:
        report["mix"] = run_mix_sensitivity(
            mix_pairings, rounds=rounds, geom=geom, n_devices=n_devices,
            mix_run=mix_run)
    if noc_models:
        report["noc"] = run_noc_sensitivity(
            app, archs, noc_models, kernels_per_app=kernels_per_app,
            rounds=rounds, geom=geom, n_devices=n_devices)
    # provenance block; every compare_* gates only the baseline's own
    # sections, so adding it never breaks committed baselines
    from repro.obs.manifest import run_manifest
    report["manifest"] = run_manifest(
        phases={"sweep": run.report.wall_s})
    return report


def to_markdown(report: dict) -> str:
    """Render the report as a markdown sensitivity table."""
    cfg = report["config"]
    lines = [
        f"# Sensitivity report — app `{cfg['app']}`",
        "",
        f"archs: {', '.join(cfg['archs'])} · "
        f"kernels/app: {cfg['kernels_per_app']} · "
        f"rounds: {cfg['rounds'] if cfg['rounds'] else 'full'} · "
        f"executables: {report['sweep']['n_executables']}",
        "",
        "| knob | value | arch | IPC | L1 hit | remote hit | NoC flits |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in report["cells"]:
        lines.append(
            f"| {c['knob']} | {c['value']:g} | {c['arch']} "
            f"| {c['ipc']:.3f} | {c['l1_hit_rate']:.4f} "
            f"| {c['remote_hit_rate']:.4f} | {c['noc_flits']:.0f} |")
    mix = report.get("mix")
    if mix:
        lines += [
            "",
            "## Multi-tenant fairness (weighted speedup ideal = 2, "
            "unfairness ideal = 1)",
            "",
            f"pairings: "
            f"{', '.join('x'.join(p) for p in mix['config']['pairings'])}"
            f" · executables: {mix['sweep']['n_executables']}",
            "",
            "| mix | arch | weighted speedup | unfairness | mix IPC |",
            "|---|---|---|---|---|",
        ]
        for c in mix["cells"]:
            lines.append(
                f"| {c['mix']} | {c['arch']} "
                f"| {c['weighted_speedup']:.3f} | {c['unfairness']:.3f} "
                f"| {c['ipc']:.2f} |")
    noc = report.get("noc")
    if noc:
        lines += [
            "",
            "## Interconnect topology sensitivity",
            "",
            f"models: {', '.join(noc['config']['nocs'])} · "
            f"noc_bw: {', '.join(f'{v:g}' for v in noc['config']['noc_bw'])}"
            f" · executables: {noc['sweep']['n_executables']}",
            "",
            "| arch | noc | noc_bw | IPC | queue delay | hotspot util |",
            "|---|---|---|---|---|---|",
        ]
        for c in noc["cells"]:
            lines.append(
                f"| {c['arch']} | {c['noc']} | {c['noc_bw']:g} "
                f"| {c['ipc']:.3f} | {c['noc_mean_queue_delay']:.2f} "
                f"| {c['noc_max_link_util']:.4f} |")
    return "\n".join(lines) + "\n"


def write_report(path: str, report: dict) -> str:
    """Write ``report`` as JSON, plus the markdown table next to it.

    Returns the markdown path (``<path minus .json>.md``).
    """
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    base, ext = os.path.splitext(path)
    md_path = (base if ext == ".json" else path) + ".md"
    with open(md_path, "w") as f:
        f.write(to_markdown(report))
    return md_path


def load_report(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _cell_key(cell: dict) -> tuple:
    return (cell["arch"], cell["knob"], cell["value"])


def _mix_cell_key(cell: dict) -> tuple:
    return (cell["mix"], cell["arch"])


def _noc_cell_key(cell: dict) -> tuple:
    return (cell["arch"], cell["noc"], cell["noc_bw"])


def _compare_section(failures: List[str], baseline: dict, candidate: dict,
                     *, key_fn, metric: str, metric_label: str,
                     rtol: float, label: str) -> None:
    """Shared cell-diff logic for the solo and mix sections."""
    base_exec = baseline["sweep"]["n_executables"]
    cand_exec = candidate["sweep"]["n_executables"]
    if cand_exec > base_exec:
        failures.append(
            f"{label} executable count grew: {base_exec} -> {cand_exec} "
            "(policy stacking / geometry batching regression)")
    cand_cells = {key_fn(c): c for c in candidate["cells"]}
    for base_cell in baseline["cells"]:
        key = key_fn(base_cell)
        cell = cand_cells.get(key)
        if cell is None:
            failures.append(f"{label} cell missing from candidate: {key}")
            continue
        base_v, cand_v = base_cell[metric], cell[metric]
        drift = abs(cand_v - base_v) / abs(base_v)
        if drift > rtol:
            failures.append(
                f"{label} {metric_label} drift {drift:+.1%} beyond "
                f"±{rtol:.0%} at {key}: {base_v:.3f} -> {cand_v:.3f}")


def compare_reports(baseline: dict, candidate: dict, *,
                    ipc_rtol: float = 0.10) -> List[str]:
    """Regression-gate diff; returns human-readable failure strings.

    Fails on: schema *downgrade* or config mismatch (the runs are not
    comparable), missing cells, per-cell IPC drift beyond ``ipc_rtol``
    in *either* direction (improvements require a conscious baseline
    update too), and executable-count growth (compile-count
    regressions) — per section.

    Schema compatibility: a candidate at a **newer** schema than the
    baseline is legal — the gate compares the sections and config keys
    the baseline carries and ignores candidate-only additions (e.g. a
    schema-1 baseline gates a schema-2/3 candidate on its solo cells
    and tolerates the new ``mix``/``noc`` sections). The ``mix``
    section is gated (on ``weighted_speedup`` drift and its own
    executable count) only when both reports carry it, and likewise
    the ``noc`` topology section (on per-cell IPC drift).
    """
    failures: List[str] = []
    base_schema = baseline.get("schema")
    cand_schema = candidate.get("schema")
    if base_schema is None or cand_schema is None \
            or cand_schema < base_schema:
        return [f"schema mismatch: baseline {base_schema} "
                f"vs candidate {cand_schema} (candidate must be at the "
                "baseline's schema or newer)"]
    for key, value in baseline["config"].items():
        if candidate["config"].get(key) != value:
            return [f"config mismatch — reports are not comparable: "
                    f"baseline {baseline['config']} "
                    f"vs candidate {candidate['config']}"]

    _compare_section(failures, baseline, candidate, key_fn=_cell_key,
                     metric="ipc", metric_label="IPC", rtol=ipc_rtol,
                     label="solo")
    if "mix" in baseline:
        if "mix" not in candidate:
            failures.append("mix section missing from candidate "
                            "(baseline carries one)")
        else:
            _compare_section(failures, baseline["mix"], candidate["mix"],
                             key_fn=_mix_cell_key,
                             metric="weighted_speedup",
                             metric_label="weighted-speedup",
                             rtol=ipc_rtol, label="mix")
    if "noc" in baseline:
        if "noc" not in candidate:
            failures.append("noc section missing from candidate "
                            "(baseline carries one)")
        else:
            _compare_section(failures, baseline["noc"], candidate["noc"],
                             key_fn=_noc_cell_key, metric="ipc",
                             metric_label="IPC", rtol=ipc_rtol,
                             label="noc")
    return failures


def compare_simspeed(baseline: dict, candidate: dict, *,
                     speedup_rtol: float = 0.30,
                     rps_rtol: Optional[float] = None) -> List[str]:
    """Regression gate for ``benchmarks.sim_speed`` throughput reports
    (``kind == "simspeed"``); returns human-readable failure strings.

    The blocking check is the **fused speedup ratio** — rounds/sec of
    the fused ``lax`` backend over the historical ``lax_unfused``
    chain, measured back-to-back on one host, so it is
    machine-portable: the gate fails when the candidate's ratio falls
    below the baseline's by more than ``speedup_rtol`` (a one-sided
    check — a *faster* fused path is never a regression). Absolute
    rounds/sec is host-dependent and only gated when ``rps_rtol`` is
    given (for same-runner comparisons); the nightly trend tracking
    (``scripts/bench_trend.py``) watches it informationally either
    way. Also fails on config mismatch, schema downgrade, backends
    missing from the candidate, and per-backend executable-count
    growth (a stacking regression would show up as compiles, not
    seconds, at CI's round counts).
    """
    for rep, who in ((baseline, "baseline"), (candidate, "candidate")):
        if rep.get("kind") != "simspeed":
            return [f"{who} is not a simspeed report "
                    f"(kind={rep.get('kind')!r})"]
    if candidate.get("schema", 0) < baseline.get("schema", 0):
        return [f"schema downgrade: baseline {baseline.get('schema')} "
                f"vs candidate {candidate.get('schema')}"]
    for key, value in baseline["config"].items():
        if candidate["config"].get(key) != value:
            return [f"config mismatch — reports are not comparable: "
                    f"baseline {baseline['config']} "
                    f"vs candidate {candidate['config']}"]

    failures: List[str] = []
    cand_cells = {c["backend"]: c for c in candidate["cells"]}
    for base_cell in baseline["cells"]:
        backend = base_cell["backend"]
        cell = cand_cells.get(backend)
        if cell is None:
            failures.append(f"backend missing from candidate: {backend}")
            continue
        if cell["n_executables"] > base_cell["n_executables"]:
            failures.append(
                f"{backend} executable count grew: "
                f"{base_cell['n_executables']} -> "
                f"{cell['n_executables']}")
        if rps_rtol is not None:
            base_v, cand_v = (base_cell["rounds_per_sec"],
                              cell["rounds_per_sec"])
            if cand_v < base_v * (1 - rps_rtol):
                failures.append(
                    f"{backend} rounds/sec fell beyond -{rps_rtol:.0%}: "
                    f"{base_v:.0f} -> {cand_v:.0f}")
    base_ratio = baseline.get("headline", {}).get("fused_speedup")
    cand_ratio = candidate.get("headline", {}).get("fused_speedup")
    if base_ratio is not None:
        if cand_ratio is None:
            failures.append("fused_speedup headline missing from "
                            "candidate")
        elif cand_ratio < base_ratio * (1 - speedup_rtol):
            failures.append(
                f"fused speedup fell beyond -{speedup_rtol:.0%}: "
                f"{base_ratio:.3f}x -> {cand_ratio:.3f}x "
                "(the fused lax probe path lost its win over "
                "lax_unfused)")
    return failures


def _serving_cell_key(cell: dict) -> tuple:
    # pre-batching (schema 1) cells carry no "slots" key: B=1
    return (cell["shards"], cell["mix"], cell["policy"],
            cell.get("slots", 1))


#: Absolute floor on the batched modeled-throughput ratio (B=max vs
#: B=1 requests per kcycle): the batched-admission acceptance bar.
BATCHED_SPEEDUP_FLOOR = 1.5


def compare_serving(baseline: dict, candidate: dict, *,
                    hit_rtol: float = 0.005,
                    latency_rtol: Optional[float] = None,
                    batched_rtol: float = 0.15,
                    wall_rtol: Optional[float] = None) -> List[str]:
    """Regression gate for ``benchmarks.fig_serving_scale`` reports
    (``kind == "serving"``); returns human-readable failure strings.

    The serving engine is integer-deterministic on a seeded stream, so
    the blocking checks are tight: per (shards x mix x policy x slots)
    cell, **probe-message counts gate exactly** (the paper's claim —
    ``ata`` must stay at zero, and a drifting ``broadcast`` count
    means the probe accounting changed) and **hit rate** within
    ``hit_rtol`` (nominally exact too; the tolerance absorbs only the
    float division). Cells without a ``slots`` key (schema-1
    baselines) compare as ``slots=1``, so an old baseline keeps gating
    the new per-B grid's B=1 cells. Modeled p99 latency is gated only
    when ``latency_rtol`` is given (it folds in NoC queue state and
    cost constants that legitimately move with the cost model).

    The ``batched_model_speedup`` headline — worst-cell modeled
    requests-per-kcycle ratio, B=max vs B=1 — gates **one-sided**: it
    must clear both the absolute :data:`BATCHED_SPEEDUP_FLOOR` (the
    batched-admission acceptance bar; the ratio is deterministic, so
    this is machine-portable like the simspeed fused-speedup gate) and
    ``baseline * (1 - batched_rtol)``. The companion
    ``batched_wall_speedup`` (host wall-clock ratio) is gated only
    when ``wall_rtol`` is given — batched replay is slot-sequential
    by contract, so wall time tracks admitted blocks and the ratio
    hovers near 1x; the opt-in floor only catches pathological
    slowdowns on same-runner setups. Per-cell wall-clock throughput is
    never gated — it is host-dependent and tracked by the nightly
    trend instead. Also fails on kind/config mismatch, schema
    downgrade, and missing cells.
    """
    for rep, who in ((baseline, "baseline"), (candidate, "candidate")):
        if rep.get("kind") != "serving":
            return [f"{who} is not a serving report "
                    f"(kind={rep.get('kind')!r})"]
    if candidate.get("schema", 0) < baseline.get("schema", 0):
        return [f"schema downgrade: baseline {baseline.get('schema')} "
                f"vs candidate {candidate.get('schema')}"]
    for key, value in baseline["config"].items():
        if candidate["config"].get(key) != value:
            return [f"config mismatch — reports are not comparable: "
                    f"baseline {baseline['config']} "
                    f"vs candidate {candidate['config']}"]

    failures: List[str] = []
    cand_cells = {_serving_cell_key(c): c for c in candidate["cells"]}
    for base_cell in baseline["cells"]:
        key = _serving_cell_key(base_cell)
        cell = cand_cells.get(key)
        if cell is None:
            failures.append(f"serving cell missing from candidate: {key}")
            continue
        if cell["probe_messages"] != base_cell["probe_messages"]:
            failures.append(
                f"probe-message count changed at {key}: "
                f"{base_cell['probe_messages']} -> "
                f"{cell['probe_messages']} (directory/probe accounting "
                "drifted — the stream is seeded, this must be exact)")
        if cell["requests"] != base_cell["requests"]:
            failures.append(
                f"request count changed at {key}: "
                f"{base_cell['requests']} -> {cell['requests']} "
                "(stream generation drifted under an identical config)")
        base_v, cand_v = base_cell["hit_rate"], cell["hit_rate"]
        drift = abs(cand_v - base_v) / max(abs(base_v), 1e-9)
        if drift > hit_rtol:
            failures.append(
                f"hit-rate drift {drift:+.2%} beyond ±{hit_rtol:.1%} "
                f"at {key}: {base_v:.4f} -> {cand_v:.4f}")
        if latency_rtol is not None:
            base_v, cand_v = base_cell["p99_latency"], cell["p99_latency"]
            drift = abs(cand_v - base_v) / max(abs(base_v), 1e-9)
            if drift > latency_rtol:
                failures.append(
                    f"p99-latency drift {drift:+.2%} beyond "
                    f"±{latency_rtol:.0%} at {key}: "
                    f"{base_v:.1f} -> {cand_v:.1f}")

    base_head = baseline.get("headline", {})
    cand_head = candidate.get("headline", {})
    base_ratio = base_head.get("batched_model_speedup")
    if base_ratio is not None:
        cand_ratio = cand_head.get("batched_model_speedup")
        if cand_ratio is None:
            failures.append("batched_model_speedup headline missing "
                            "from candidate")
        else:
            floor = max(BATCHED_SPEEDUP_FLOOR,
                        base_ratio * (1 - batched_rtol))
            if cand_ratio < floor:
                failures.append(
                    f"batched modeled speedup fell below "
                    f"{floor:.3f}x (abs floor "
                    f"{BATCHED_SPEEDUP_FLOOR}x, baseline "
                    f"{base_ratio:.3f}x -{batched_rtol:.0%}): "
                    f"{cand_ratio:.3f}x at "
                    f"B={cand_head.get('batched_slots')} "
                    "(batched admission stopped amortizing rounds)")
        if wall_rtol is not None:
            base_w = base_head.get("batched_wall_speedup")
            cand_w = cand_head.get("batched_wall_speedup")
            if base_w is not None and cand_w is not None \
                    and cand_w < base_w * (1 - wall_rtol):
                failures.append(
                    f"batched wall speedup fell beyond "
                    f"-{wall_rtol:.0%}: {base_w:.3f}x -> "
                    f"{cand_w:.3f}x")
    return failures
