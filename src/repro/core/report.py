"""Sensitivity reports over :class:`~repro.core.sweep.SweepGrid` runs.

:func:`run_sensitivity` sweeps the contention-policy zoo over widened
geometry axes around the paper's Table-II point —

    l1_ways : L1 associativity (structural: regroups per shape)
    noc_bw  : probe-network bandwidth (traced scalar)
    hide    : warp-level latency-hiding depth (traced scalar)

— every (arch x knob-value x kernel) point through *one* grid run, and
aggregates per (arch x geometry) cell into a machine-readable report
dict: IPC, L1 hit rate, remote-probe rate, NoC flits, plus the grid's
:class:`~repro.core.sweep.SweepReport` accounting. :func:`write_report`
serializes it as ``BENCH_sensitivity.json`` with a markdown sensitivity
table alongside; ``benchmarks.run --report-json`` wires it into the
benchmark driver.

The report doubles as CI's benchmark-regression gate:
:func:`compare_reports` diffs a fresh report against a committed
baseline and flags per-cell IPC drift beyond a tolerance or
executable-count growth (``scripts/check_bench_regression.py`` is the
thin CLI; the sharded-sweep-smoke workflow job runs it on every PR).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.geometry import GpuGeometry, PAPER_GEOMETRY
from repro.core.metrics import AppResult, app_traces, kernel_range
from repro.core.sweep import SweepGrid, SweepPoint

SCHEMA_VERSION = 1

#: The zoo comparison set: the paper's poles, the probe-broadcast
#: baseline (the only ``noc_bw`` consumer), and both new policies.
SENSITIVITY_ARCHS: Tuple[str, ...] = ("private", "remote", "ata", "ciao",
                                      "victim")

#: Widened geometry axes (ROADMAP follow-on); middle value = paper point.
SENSITIVITY_KNOBS: Dict[str, Tuple] = {
    "l1_ways": (32, 64, 128),
    "noc_bw": (8.0, 16.0, 32.0),
    "hide": (5.0, 10.0, 20.0),
}

#: Metrics reported per (arch x geometry) cell.
CELL_METRICS = ("ipc", "l1_hit_rate", "remote_hit_rate", "noc_flits",
                "l1_latency")


def run_sensitivity(app: str = "HS3D",
                    archs: Sequence[str] = SENSITIVITY_ARCHS,
                    knobs: Optional[Dict[str, Tuple]] = None,
                    kernels_per_app: Optional[int] = 1,
                    rounds: Optional[int] = None,
                    geom: GpuGeometry = PAPER_GEOMETRY,
                    n_devices: Optional[int] = None) -> dict:
    """One grid run over (arch x knob-value x kernel); report dict out."""
    knobs = dict(SENSITIVITY_KNOBS if knobs is None else knobs)
    archs = tuple(archs)
    traces = app_traces(app, geom, kernel_range(app, kernels_per_app),
                        rounds=rounds)
    # Each knob lists the paper point among its values, so several cells
    # share one (arch, geometry): simulate each unique pair once and fan
    # the result out to every cell that references it.
    labels: List[Tuple[str, object, str, GpuGeometry]] = []
    start: Dict[Tuple[str, GpuGeometry], int] = {}
    points: List[SweepPoint] = []
    for knob, values in knobs.items():
        for value in values:
            g = dataclasses.replace(geom, **{knob: value})
            for arch in archs:
                labels.append((knob, value, arch, g))
                if (arch, g) not in start:
                    start[(arch, g)] = len(points)
                    points.extend(SweepPoint(arch, g, t) for t in traces)
    grid = SweepGrid.from_points(points)
    run = grid.run(n_devices=n_devices)

    cells = []
    per_cell = len(traces)
    for knob, value, arch, g in labels:
        lo = start[(arch, g)]
        agg = AppResult(app, arch, run.results[lo:lo + per_cell])
        cell = {"knob": knob, "value": value, "arch": arch}
        for metric in CELL_METRICS:
            cell[metric] = float(getattr(agg, metric))
        cells.append(cell)

    return {
        "schema": SCHEMA_VERSION,
        "config": {
            "app": app,
            "archs": list(archs),
            "knobs": {k: list(v) for k, v in knobs.items()},
            "kernels_per_app": kernels_per_app,
            "rounds": rounds,
        },
        "sweep": {
            "n_points": run.report.n_points,
            "n_executables": run.report.n_executables,
            "n_compiles": run.report.n_compiles,
            "n_devices": run.report.n_devices,
            "wall_s": round(run.report.wall_s, 3),
        },
        "cells": cells,
    }


def to_markdown(report: dict) -> str:
    """Render the report as a markdown sensitivity table."""
    cfg = report["config"]
    lines = [
        f"# Sensitivity report — app `{cfg['app']}`",
        "",
        f"archs: {', '.join(cfg['archs'])} · "
        f"kernels/app: {cfg['kernels_per_app']} · "
        f"rounds: {cfg['rounds'] if cfg['rounds'] else 'full'} · "
        f"executables: {report['sweep']['n_executables']}",
        "",
        "| knob | value | arch | IPC | L1 hit | remote hit | NoC flits |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in report["cells"]:
        lines.append(
            f"| {c['knob']} | {c['value']:g} | {c['arch']} "
            f"| {c['ipc']:.3f} | {c['l1_hit_rate']:.4f} "
            f"| {c['remote_hit_rate']:.4f} | {c['noc_flits']:.0f} |")
    return "\n".join(lines) + "\n"


def write_report(path: str, report: dict) -> str:
    """Write ``report`` as JSON, plus the markdown table next to it.

    Returns the markdown path (``<path minus .json>.md``).
    """
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    base, ext = os.path.splitext(path)
    md_path = (base if ext == ".json" else path) + ".md"
    with open(md_path, "w") as f:
        f.write(to_markdown(report))
    return md_path


def load_report(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _cell_key(cell: dict) -> tuple:
    return (cell["arch"], cell["knob"], cell["value"])


def compare_reports(baseline: dict, candidate: dict, *,
                    ipc_rtol: float = 0.10) -> List[str]:
    """Regression-gate diff; returns human-readable failure strings.

    Fails on: schema/config mismatch (the runs are not comparable),
    missing cells, per-cell IPC drift beyond ``ipc_rtol`` in *either*
    direction (improvements require a conscious baseline update too),
    and executable-count growth (compile-count regressions).
    """
    failures: List[str] = []
    if baseline.get("schema") != candidate.get("schema"):
        return [f"schema mismatch: baseline {baseline.get('schema')} "
                f"vs candidate {candidate.get('schema')}"]
    if baseline["config"] != candidate["config"]:
        return [f"config mismatch — reports are not comparable: "
                f"baseline {baseline['config']} "
                f"vs candidate {candidate['config']}"]

    base_exec = baseline["sweep"]["n_executables"]
    cand_exec = candidate["sweep"]["n_executables"]
    if cand_exec > base_exec:
        failures.append(
            f"executable count grew: {base_exec} -> {cand_exec} "
            "(policy stacking / geometry batching regression)")

    cand_cells = {_cell_key(c): c for c in candidate["cells"]}
    for base_cell in baseline["cells"]:
        key = _cell_key(base_cell)
        cell = cand_cells.get(key)
        if cell is None:
            failures.append(f"cell missing from candidate: {key}")
            continue
        base_ipc, cand_ipc = base_cell["ipc"], cell["ipc"]
        drift = abs(cand_ipc - base_ipc) / abs(base_ipc)
        if drift > ipc_rtol:
            failures.append(
                f"IPC drift {drift:+.1%} beyond ±{ipc_rtol:.0%} at "
                f"{key}: {base_ipc:.3f} -> {cand_ipc:.3f}")
    return failures
