"""Experiment driver + paper-figure summaries over the simulator.

``run_app``/``run_suite`` sweep through
:class:`repro.core.sweep.SweepGrid`: every requested (arch, kernel)
point of the suite goes into one grid, which stacks same-dataflow
architectures into shared executables, batches the trace axis, and
shards the stacked points across the host's devices — one compilation
per (arch dataflow group, trace shape) instead of one ``jax.jit`` trace
per kernel, and one device dispatch per bucket.

``run_mixes`` extends the same pattern to multi-tenant co-scheduling:
every composed :class:`~repro.core.trace.WorkloadMix` trace plus every
per-slot solo baseline goes into one grid run, and
:class:`MixResult` turns the per-app attribution into the fairness
metrics (weighted speedup, unfairness) the serving-domain scenarios
need.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, Iterable, List, NamedTuple, Optional,
                    Sequence)

import numpy as np

from repro.core.geometry import GpuGeometry, PAPER_GEOMETRY
from repro.core.simulator import ARCHITECTURES, SimResult, Trace
from repro.core.sweep import SweepGrid, SweepPoint, SweepReport
from repro.core.trace import APPS, AppParams, WorkloadMix, make_trace


def _nanmean(values: Iterable[float]) -> float:
    """Mean over non-NaN entries; NaN only if *every* entry is NaN.

    ``SimResult.l1_latency`` is documented to be NaN for kernels where
    no load was ever fully served inside the L1 complex (all-streaming
    traces); a plain ``np.mean`` would let one such kernel poison the
    whole app figure.
    """
    vals = [v for v in values if not np.isnan(v)]
    return float(np.mean(vals)) if vals else float("nan")


@dataclasses.dataclass
class AppResult:
    app: str
    arch: str
    per_kernel: List[SimResult]

    @property
    def ipc(self) -> float:
        # whole-app IPC = total instructions / total cycles across kernels
        insns = sum(r.instructions for r in self.per_kernel)
        cycles = sum(r.cycles for r in self.per_kernel)
        return insns / cycles

    @property
    def l1_latency(self) -> float:
        return _nanmean(r.l1_latency for r in self.per_kernel)

    @property
    def l1_hit_rate(self) -> float:
        return _nanmean(r.l1_hit_rate for r in self.per_kernel)

    @property
    def remote_hit_rate(self) -> float:
        # remote-probe service rate: requests served by a peer L1
        return _nanmean(r.remote_hit_rate for r in self.per_kernel)

    @property
    def noc_flits(self) -> float:
        return float(sum(r.noc_flits for r in self.per_kernel))

    @property
    def noc_flits_injected(self) -> float:
        # traffic actually routed by the modeled interconnect
        # (repro.core.noc) — unlike `noc_flits`, which also counts the
        # memory-side L2/write-back flits the NoC layer excludes
        return float(sum(r.noc.flits_injected for r in self.per_kernel))

    @property
    def noc_mean_queue_delay(self) -> float:
        # interconnect queueing (repro.core.noc): 0.0 under `ideal`
        return _nanmean(r.noc.mean_queue_delay for r in self.per_kernel)

    @property
    def noc_max_link_util(self) -> float:
        # hotspot link utilization, worst kernel
        return float(max(r.noc.max_link_util for r in self.per_kernel))

    @property
    def l2_accesses(self) -> float:
        return float(sum(r.l2_accesses for r in self.per_kernel))


def kernel_range(app: str,
                 kernels_per_app: Optional[int]) -> Optional[range]:
    """The kernel subset a ``kernels_per_app`` budget selects for ``app``
    (None = all kernels). Shared by run_suite and the benchmark cache."""
    if not kernels_per_app:
        return None
    return range(min(kernels_per_app, APPS[app].n_kernels))


def app_traces(app: str, geom: GpuGeometry = PAPER_GEOMETRY,
               kernels: Optional[Iterable[int]] = None,
               params: Optional[AppParams] = None,
               rounds: Optional[int] = None) -> List[Trace]:
    """The per-kernel traces one ``run_app`` call simulates.

    ``rounds`` truncates every kernel (CI smoke runs use this to keep the
    sweep engine exercised without paying full-trace cost).
    """
    p = params if params is not None else APPS[app]
    if rounds is not None:
        p = dataclasses.replace(p, rounds=rounds)
    ks = list(kernels) if kernels is not None else range(p.n_kernels)
    return [make_trace(p, n_cores=geom.n_cores, kernel=k) for k in ks]


def sweep_cells(cells: Iterable[tuple]) -> Dict[object, List[SimResult]]:
    """Sweep many (key, arch, geom, traces) cells in one grid run.

    The shared regrouping seam under :func:`run_suite` and the benchmark
    caches: every cell's traces become grid points, one
    :class:`SweepGrid` run sweeps them all (same-dataflow architectures
    share executables, stacked points shard across devices), and the
    per-point results regroup into ``{key: [SimResult per trace, in
    order]}``.
    """
    points: List[SweepPoint] = []
    owners: List[object] = []
    for key, arch, geom, traces in cells:
        for tr in traces:
            points.append(SweepPoint(arch, geom, tr))
            owners.append(key)
    if not points:
        return {}
    run = SweepGrid.from_points(points).run()
    out: Dict[object, List[SimResult]] = {}
    for key, r in zip(owners, run.results):
        out.setdefault(key, []).append(r)
    return out


def grid_app_results(grid: SweepGrid, results: Sequence[SimResult],
                     app: str) -> Dict[tuple, AppResult]:
    """{(arch, geom, noc): AppResult} over one grid's aligned results.

    Keyed off ``grid.points`` — the authoritative point list — rather
    than any assumed axis-enumeration order, so a reordering of
    ``SweepGrid``'s product (or a caller-side index slip) cannot
    silently misattribute per-cell aggregates. All of a cell's traces
    fold into one :class:`AppResult`, in point order.
    """
    grouped: Dict[tuple, List[SimResult]] = {}
    for pt, r in zip(grid.points, results):
        grouped.setdefault((pt.arch, pt.geom, pt.noc), []).append(r)
    return {key: AppResult(app, key[0], rs)
            for key, rs in grouped.items()}


def run_app(app: str, arch: str, geom: GpuGeometry = PAPER_GEOMETRY,
            kernels: Optional[Iterable[int]] = None,
            params: Optional[AppParams] = None,
            rounds: Optional[int] = None) -> AppResult:
    """All kernels of one app through one architecture — one grid run."""
    traces = app_traces(app, geom, kernels, params, rounds)
    return AppResult(app, arch,
                     SweepGrid([arch], [geom], traces).run().results)


def run_suite(apps: Optional[Iterable[str]] = None,
              archs: Iterable[str] = ARCHITECTURES,
              geom: GpuGeometry = PAPER_GEOMETRY,
              kernels_per_app: Optional[int] = None,
              rounds: Optional[int] = None,
              ) -> Dict[str, Dict[str, AppResult]]:
    """{app: {arch: AppResult}} over the benchmark suite.

    The whole (app-kernel x arch) product goes into *one*
    :class:`SweepGrid` run via :func:`sweep_cells`.
    """
    apps = list(apps or APPS)
    archs = tuple(archs)
    traces = {app: app_traces(app, geom,
                              kernel_range(app, kernels_per_app),
                              rounds=rounds)
              for app in apps}
    results = sweep_cells(((app, arch), arch, geom, traces[app])
                          for app in apps for arch in archs)
    return {app: {arch: AppResult(app, arch, results[(app, arch)])
                  for arch in archs}
            for app in apps}


@dataclasses.dataclass
class MixResult:
    """Fairness summary of one (mix, arch) co-scheduling run.

    ``shared`` is the composed-trace run (its ``per_app`` block carries
    the attribution); ``solo`` holds one full-machine baseline run per
    mix slot over the *same* sliced/staggered addresses
    (:meth:`WorkloadMix.component_traces`), so the slowdowns below
    measure interference, not address-map artifacts.

    Because a slot owns only ``k_i`` of the machine's ``C`` cores while
    its solo baseline runs on all ``C``, speedups compare *per-core*
    IPC: ``slowdown_i = (solo_ipc_i / C) / (shared_ipc_i / k_i)``.
    ``weighted_speedup`` is then the summed normalized progress
    ``Σ 1/slowdown_i`` (ideal = n_apps, the classic Snavely–Tullsen
    weighted speedup over machine-share-normalized rates), and
    ``unfairness`` is ``max slowdown / min slowdown`` (ideal = 1)
    [MASK, arXiv 1708.04911].
    """
    mix: WorkloadMix
    arch: str
    shared: SimResult
    solo: List[SimResult]

    @property
    def n_cores(self) -> int:
        return sum(a.cores for a in self.shared.per_app)

    @property
    def per_app_ipc(self) -> List[float]:
        return [a.ipc for a in self.shared.per_app]

    @property
    def per_app_l1_hit_rate(self) -> List[float]:
        return [a.l1_hit_rate for a in self.shared.per_app]

    @property
    def slowdowns(self) -> List[float]:
        C = self.n_cores
        out = []
        for a, s in zip(self.shared.per_app, self.solo):
            shared_per_core = a.ipc / a.cores
            solo_per_core = s.ipc / C
            out.append(solo_per_core / shared_per_core)
        return out

    @property
    def weighted_speedup(self) -> float:
        return float(sum(1.0 / s for s in self.slowdowns))

    @property
    def unfairness(self) -> float:
        sd = self.slowdowns
        return float(max(sd) / min(sd))


class MixRun(NamedTuple):
    """``run_mixes`` output: results plus the grid's accounting."""
    results: Dict[str, Dict[str, MixResult]]   # {mix_id: {arch: ...}}
    report: SweepReport


def run_mixes(mixes: Sequence[WorkloadMix],
              archs: Iterable[str] = ARCHITECTURES,
              geom: GpuGeometry = PAPER_GEOMETRY,
              rounds: Optional[int] = None,
              seed: int = 0,
              n_devices: Optional[int] = None) -> MixRun:
    """Sweep (mix x arch) with solo baselines in *one* grid run.

    Every composed mix trace and every per-slot solo baseline trace of
    every architecture goes into a single :class:`SweepGrid` run: solo
    points share the ordinary single-app executables, mix points bucket
    by (dataflow group, trace kind) — no per-mix recompilation.
    """
    archs = tuple(archs)
    if rounds is not None:
        mixes = [dataclasses.replace(m, rounds=rounds) for m in mixes]
    mixes = list(mixes)
    ids = [m.mix_id for m in mixes]
    if len(set(ids)) != len(ids):
        raise ValueError(f"duplicate mix ids in {ids}")

    points: List[SweepPoint] = []
    owners: List[tuple] = []
    for mix, mid in zip(mixes, ids):
        shared = mix.compose(geom.n_cores, seed=seed)
        comps = mix.component_traces(geom.n_cores, seed=seed)
        for arch in archs:
            points.append(SweepPoint(arch, geom, shared))
            owners.append(("shared", mid, arch))
            for tr in comps:
                points.append(SweepPoint(arch, geom, tr))
                owners.append(("solo", mid, arch))
    run = SweepGrid.from_points(points).run(n_devices=n_devices)

    grouped: Dict[tuple, List[SimResult]] = {}
    for key, r in zip(owners, run.results):
        grouped.setdefault(key, []).append(r)
    results = {
        mid: {arch: MixResult(mix, arch,
                              shared=grouped[("shared", mid, arch)][0],
                              solo=grouped[("solo", mid, arch)])
              for arch in archs}
        for mix, mid in zip(mixes, ids)}
    return MixRun(results=results, report=run.report)


def normalized_ipc(suite: Dict[str, Dict[str, AppResult]],
                   base: str = "private") -> Dict[str, Dict[str, float]]:
    return {app: {arch: r[arch].ipc / r[base].ipc for arch in r}
            for app, r in suite.items()}


def geomean(xs: Iterable[float]) -> float:
    """Geometric mean; rejects NaN/inf/non-positive inputs loudly.

    A single NaN (e.g. a latency ratio built from an all-streaming
    kernel) or a non-positive value would otherwise propagate a silent
    NaN into headline figure numbers.
    """
    arr = np.asarray(list(xs), dtype=float)
    if arr.size == 0:
        raise ValueError("geomean of an empty sequence")
    if not np.all(np.isfinite(arr)) or np.any(arr <= 0):
        raise ValueError(
            f"geomean needs finite positive inputs, got {arr.tolist()}")
    return float(np.exp(np.mean(np.log(arr))))
