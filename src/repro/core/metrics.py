"""Experiment driver + paper-figure summaries over the simulator."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.geometry import GpuGeometry, PAPER_GEOMETRY
from repro.core.simulator import ARCHITECTURES, SimResult, simulate
from repro.core.workloads import APPS, AppParams, make_trace


@dataclasses.dataclass
class AppResult:
    app: str
    arch: str
    per_kernel: List[SimResult]

    @property
    def ipc(self) -> float:
        # whole-app IPC = total instructions / total cycles across kernels
        insns = sum(r.instructions for r in self.per_kernel)
        cycles = sum(r.cycles for r in self.per_kernel)
        return insns / cycles

    @property
    def l1_latency(self) -> float:
        return float(np.mean([r.l1_latency for r in self.per_kernel]))

    @property
    def l1_hit_rate(self) -> float:
        return float(np.mean([r.l1_hit_rate for r in self.per_kernel]))

    @property
    def l2_accesses(self) -> float:
        return float(sum(r.l2_accesses for r in self.per_kernel))


def run_app(app: str, arch: str, geom: GpuGeometry = PAPER_GEOMETRY,
            kernels: Optional[Iterable[int]] = None,
            params: Optional[AppParams] = None) -> AppResult:
    p = params if params is not None else APPS[app]
    ks = list(kernels) if kernels is not None else range(p.n_kernels)
    results = [simulate(arch, make_trace(p, n_cores=geom.n_cores, kernel=k),
                        geom) for k in ks]
    return AppResult(app, arch, results)


def run_suite(apps: Optional[Iterable[str]] = None,
              archs: Iterable[str] = ARCHITECTURES,
              geom: GpuGeometry = PAPER_GEOMETRY,
              kernels_per_app: Optional[int] = None,
              ) -> Dict[str, Dict[str, AppResult]]:
    """{app: {arch: AppResult}} over the benchmark suite."""
    out: Dict[str, Dict[str, AppResult]] = {}
    for app in (apps or APPS):
        ks = (range(min(kernels_per_app, APPS[app].n_kernels))
              if kernels_per_app else None)
        out[app] = {arch: run_app(app, arch, geom, kernels=ks)
                    for arch in archs}
    return out


def normalized_ipc(suite: Dict[str, Dict[str, AppResult]],
                   base: str = "private") -> Dict[str, Dict[str, float]]:
    return {app: {arch: r[arch].ipc / r[base].ipc for arch in r}
            for app, r in suite.items()}


def geomean(xs: Iterable[float]) -> float:
    xs = list(xs)
    return float(np.exp(np.mean(np.log(xs))))
