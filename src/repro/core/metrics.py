"""Experiment driver + paper-figure summaries over the simulator.

``run_app``/``run_suite`` sweep through :func:`simulate_many`, which
stacks every same-shape trace of a sweep and runs the batch as one
vmapped, jitted call — one compilation and one device dispatch per
(arch, trace-shape) instead of one ``jax.jit`` trace per kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.geometry import GpuGeometry, PAPER_GEOMETRY
from repro.core.simulator import (ARCHITECTURES, SimResult, Trace, simulate,
                                  simulate_many)
from repro.core.workloads import APPS, AppParams, make_trace


@dataclasses.dataclass
class AppResult:
    app: str
    arch: str
    per_kernel: List[SimResult]

    @property
    def ipc(self) -> float:
        # whole-app IPC = total instructions / total cycles across kernels
        insns = sum(r.instructions for r in self.per_kernel)
        cycles = sum(r.cycles for r in self.per_kernel)
        return insns / cycles

    @property
    def l1_latency(self) -> float:
        return float(np.mean([r.l1_latency for r in self.per_kernel]))

    @property
    def l1_hit_rate(self) -> float:
        return float(np.mean([r.l1_hit_rate for r in self.per_kernel]))

    @property
    def l2_accesses(self) -> float:
        return float(sum(r.l2_accesses for r in self.per_kernel))


def kernel_range(app: str,
                 kernels_per_app: Optional[int]) -> Optional[range]:
    """The kernel subset a ``kernels_per_app`` budget selects for ``app``
    (None = all kernels). Shared by run_suite and the benchmark cache."""
    if not kernels_per_app:
        return None
    return range(min(kernels_per_app, APPS[app].n_kernels))


def app_traces(app: str, geom: GpuGeometry = PAPER_GEOMETRY,
               kernels: Optional[Iterable[int]] = None,
               params: Optional[AppParams] = None,
               rounds: Optional[int] = None) -> List[Trace]:
    """The per-kernel traces one ``run_app`` call simulates.

    ``rounds`` truncates every kernel (CI smoke runs use this to keep the
    sweep engine exercised without paying full-trace cost).
    """
    p = params if params is not None else APPS[app]
    if rounds is not None:
        p = dataclasses.replace(p, rounds=rounds)
    ks = list(kernels) if kernels is not None else range(p.n_kernels)
    return [make_trace(p, n_cores=geom.n_cores, kernel=k) for k in ks]


def run_app(app: str, arch: str, geom: GpuGeometry = PAPER_GEOMETRY,
            kernels: Optional[Iterable[int]] = None,
            params: Optional[AppParams] = None,
            rounds: Optional[int] = None) -> AppResult:
    """All kernels of one app through one architecture — one batched call."""
    traces = app_traces(app, geom, kernels, params, rounds)
    return AppResult(app, arch, simulate_many(arch, traces, geom))


def run_suite(apps: Optional[Iterable[str]] = None,
              archs: Iterable[str] = ARCHITECTURES,
              geom: GpuGeometry = PAPER_GEOMETRY,
              kernels_per_app: Optional[int] = None,
              rounds: Optional[int] = None,
              ) -> Dict[str, Dict[str, AppResult]]:
    """{app: {arch: AppResult}} over the benchmark suite."""
    out: Dict[str, Dict[str, AppResult]] = {}
    for app in (apps or APPS):
        ks = kernel_range(app, kernels_per_app)
        out[app] = {arch: run_app(app, arch, geom, kernels=ks, rounds=rounds)
                    for arch in archs}
    return out


def normalized_ipc(suite: Dict[str, Dict[str, AppResult]],
                   base: str = "private") -> Dict[str, Dict[str, float]]:
    return {app: {arch: r[arch].ipc / r[base].ipc for arch in r}
            for app, r in suite.items()}


def geomean(xs: Iterable[float]) -> float:
    xs = list(xs)
    return float(np.exp(np.mean(np.log(xs))))
