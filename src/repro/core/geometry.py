"""GPU geometry + timing model constants (paper Table II).

The simulated GPU matches the paper's GPGPU-sim v4.0 configuration:
30 SIMT cores in 3 clusters of 10, 64KB 64-way L1 per core (128B lines,
8 sets, 4 banks, 32-cycle latency), 24x128KB 16-way L2 partitions
(188-cycle latency), crossbar NoC.

Service times model *occupancy* (throughput contention); latencies model
the uncontended critical path. The `hide` divisor models warp-level
latency hiding (4 GTO schedulers / core, deep multithreading).

For geometry sweeps the fields split into two kinds:

* **structure** fields (core/cluster counts, set/way/bank/partition
  counts) determine array shapes and routing-index arithmetic — they
  must be static under ``jax.jit``, and geometries are grouped by them;
* **scalar** fields (latencies, service times, rates) only enter the
  timing arithmetic — they are traced, so geometries differing only in
  scalars share one compiled executable.

:func:`split_geometry` performs the split; :class:`TracedGeometry`
recombines a static :class:`GeomStructure` with (possibly traced)
:class:`GeomScalars` behind the same attribute names, so architecture
policies run unchanged over either a concrete ``GpuGeometry`` or a
traced view.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GpuGeometry:
    # --- organization -----------------------------------------------------
    n_cores: int = 30
    cluster_size: int = 10
    # L1: 64KB / 128B lines = 512 lines, 64-way -> 8 sets, 4 banks
    l1_sets: int = 8
    l1_ways: int = 64
    l1_banks: int = 4
    # L2: 24 partitions x 128KB / 128B = 1024 lines, 16-way -> 64 sets
    l2_parts: int = 24
    l2_sets: int = 64
    l2_ways: int = 16

    # --- uncontended latencies (cycles) ------------------------------------
    lat_l1: int = 32
    lat_xbar: int = 2        # ATA intra-cluster crossbar hop (data transfer)
    lat_home: int = 16       # decoupled-sharing core->home NoC round trip
    lat_l2: int = 188
    lat_dram: int = 320
    lat_probe: int = 24      # remote-sharing probe round-trip (uncontended)

    # --- service / occupancy times (cycles per request at the resource) ----
    svc_bank: int = 8        # decoupled-sharing home-cache bank port
    svc_port: int = 2        # ATA remote-data port
    svc_probe: int = 1       # remote-sharing tag-probe service per probe
    svc_l2: int = 4          # L2 partition port
    flits_per_line: int = 4  # 128B line / 40B flit (rounded up)
    noc_bw: float = 16.0     # flits/cycle the probe network sustains/cluster

    # --- interconnect topology (repro.core.noc models) ----------------------
    # Per-port forwarding rate is noc_bw / cluster_size (the cluster's
    # probe-network bandwidth shared across its cores' remote-data
    # ports); these scalars shape the topology-aware models only — the
    # `ideal` NoC ignores them, so the paper geometry is unchanged.
    noc_drain: float = 32.0  # cycles of NoC forwarding budget per round
    noc_queue: float = 128.0  # per-port injection-queue capacity (flits)
    ring_hop: float = 2.0    # cycles per ring hop between cluster slots

    # --- core pipeline model ------------------------------------------------
    issue_rate: float = 4.0  # peak insn/cycle/core (4 GTO schedulers)
    hide: float = 10.0       # warp-level latency-hiding divisor

    @property
    def n_clusters(self) -> int:
        return self.n_cores // self.cluster_size


#: Default geometry = paper Table II.
PAPER_GEOMETRY = GpuGeometry()


#: Fields that fix array shapes / routing arithmetic (static under jit).
GEOM_STRUCTURE_FIELDS = ("n_cores", "cluster_size", "l1_sets", "l1_ways",
                         "l1_banks", "l2_parts", "l2_sets", "l2_ways")

#: Timing fields that only enter arithmetic (traceable under jit).
GEOM_SCALAR_FIELDS = ("lat_l1", "lat_xbar", "lat_home", "lat_l2",
                      "lat_dram", "lat_probe", "svc_bank", "svc_port",
                      "svc_probe", "svc_l2", "flits_per_line", "noc_bw",
                      "noc_drain", "noc_queue", "ring_hop",
                      "issue_rate", "hide")


class GeomStructure(NamedTuple):
    """The shape-determining subset of :class:`GpuGeometry` (hashable, so
    it can be a static jit argument; sweeps group geometries by it)."""
    n_cores: int
    cluster_size: int
    l1_sets: int
    l1_ways: int
    l1_banks: int
    l2_parts: int
    l2_sets: int
    l2_ways: int

    @property
    def n_clusters(self) -> int:
        return self.n_cores // self.cluster_size


class GeomScalars(NamedTuple):
    """The timing subset of :class:`GpuGeometry` as float32 leaves — a
    pytree, so it can be traced, stacked on a sweep axis, and vmapped."""
    lat_l1: jnp.ndarray
    lat_xbar: jnp.ndarray
    lat_home: jnp.ndarray
    lat_l2: jnp.ndarray
    lat_dram: jnp.ndarray
    lat_probe: jnp.ndarray
    svc_bank: jnp.ndarray
    svc_port: jnp.ndarray
    svc_probe: jnp.ndarray
    svc_l2: jnp.ndarray
    flits_per_line: jnp.ndarray
    noc_bw: jnp.ndarray
    noc_drain: jnp.ndarray
    noc_queue: jnp.ndarray
    ring_hop: jnp.ndarray
    issue_rate: jnp.ndarray
    hide: jnp.ndarray


def geom_structure(geom: GpuGeometry) -> GeomStructure:
    """The shape-determining key of ``geom`` alone — no device commits,
    so grid validation can key geometries without paying
    :func:`split_geometry`'s scalar transfers."""
    return GeomStructure(*(getattr(geom, f) for f in GEOM_STRUCTURE_FIELDS))


def split_geometry(geom: GpuGeometry):
    """``geom`` -> (static :class:`GeomStructure`, f32 :class:`GeomScalars`)."""
    scalars = GeomScalars(
        *(jnp.float32(getattr(geom, f)) for f in GEOM_SCALAR_FIELDS))
    return geom_structure(geom), scalars


class TracedGeometry:
    """A ``GpuGeometry`` look-alike over (static structure, traced scalars).

    Architecture policies and the simulator stages read geometry fields
    by attribute; this view serves structure fields as Python ints (so
    shapes and ``group_rank`` key counts stay static) and timing fields
    as float32 values that may be jit tracers (so scalar geometry sweeps
    share one executable).
    """

    __slots__ = ("structure", "scalars")

    def __init__(self, structure: GeomStructure, scalars: GeomScalars):
        object.__setattr__(self, "structure", structure)
        object.__setattr__(self, "scalars", scalars)

    def __getattr__(self, name: str):
        if name in GEOM_STRUCTURE_FIELDS:
            return getattr(self.structure, name)
        if name in GEOM_SCALAR_FIELDS:
            return getattr(self.scalars, name)
        raise AttributeError(name)

    @property
    def n_clusters(self) -> int:
        return self.structure.n_clusters
