"""GPU geometry + timing model constants (paper Table II).

The simulated GPU matches the paper's GPGPU-sim v4.0 configuration:
30 SIMT cores in 3 clusters of 10, 64KB 64-way L1 per core (128B lines,
8 sets, 4 banks, 32-cycle latency), 24x128KB 16-way L2 partitions
(188-cycle latency), crossbar NoC.

Service times model *occupancy* (throughput contention); latencies model
the uncontended critical path. The `hide` divisor models warp-level
latency hiding (4 GTO schedulers / core, deep multithreading).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GpuGeometry:
    # --- organization -----------------------------------------------------
    n_cores: int = 30
    cluster_size: int = 10
    # L1: 64KB / 128B lines = 512 lines, 64-way -> 8 sets, 4 banks
    l1_sets: int = 8
    l1_ways: int = 64
    l1_banks: int = 4
    # L2: 24 partitions x 128KB / 128B = 1024 lines, 16-way -> 64 sets
    l2_parts: int = 24
    l2_sets: int = 64
    l2_ways: int = 16

    # --- uncontended latencies (cycles) ------------------------------------
    lat_l1: int = 32
    lat_xbar: int = 2        # ATA intra-cluster crossbar hop (data transfer)
    lat_home: int = 16       # decoupled-sharing core->home NoC round trip
    lat_l2: int = 188
    lat_dram: int = 320
    lat_probe: int = 24      # remote-sharing probe round-trip (uncontended)

    # --- service / occupancy times (cycles per request at the resource) ----
    svc_bank: int = 8        # decoupled-sharing home-cache bank port
    svc_port: int = 2        # ATA remote-data port
    svc_probe: int = 1       # remote-sharing tag-probe service per probe
    svc_l2: int = 4          # L2 partition port
    flits_per_line: int = 4  # 128B line / 40B flit (rounded up)
    noc_bw: float = 16.0     # flits/cycle the probe network sustains/cluster

    # --- core pipeline model ------------------------------------------------
    issue_rate: float = 4.0  # peak insn/cycle/core (4 GTO schedulers)
    hide: float = 10.0       # warp-level latency-hiding divisor

    @property
    def n_clusters(self) -> int:
        return self.n_cores // self.cluster_size


#: Default geometry = paper Table II.
PAPER_GEOMETRY = GpuGeometry()
