"""Composable workload-trace layer.

Split from the seed-era ``repro.core.workloads`` monolith (which now
re-exports from here for backwards compatibility):

  apps.py        the calibrated :class:`AppParams` table (data only)
  generators.py  :func:`make_trace` + kernel-parameter rules + the
                 int32 address guard
  mix.py         :class:`WorkloadMix` — multi-tenant composition with
                 per-app attribution (``Trace.core_app``)
"""
from repro.core.trace.apps import (APPS, HIGH_LOCALITY, LOW_LOCALITY,
                                   AppParams)
from repro.core.trace.generators import (app_kernels, kernel_params,
                                         make_trace)
from repro.core.trace.mix import APP_STRIDE, WorkloadMix

__all__ = [
    "APPS", "HIGH_LOCALITY", "LOW_LOCALITY", "AppParams",
    "app_kernels", "kernel_params", "make_trace",
    "APP_STRIDE", "WorkloadMix",
]
