"""Composable workload-trace layer.

Split from the seed-era ``repro.core.workloads`` monolith (shim
removed in PR 7 — import from here):

  apps.py        the calibrated :class:`AppParams` table (data only)
  generators.py  :func:`make_trace` + kernel-parameter rules + the
                 int32 address guard
  mix.py         :class:`WorkloadMix` — multi-tenant composition with
                 per-app attribution (``Trace.core_app``)
  serving.py     :class:`ServingMix` / :class:`RequestStream` — the
                 multi-tenant request-stream generator feeding the
                 serving engine (``repro.serving.engine``)
"""
from repro.core.trace.apps import (APPS, HIGH_LOCALITY, LOW_LOCALITY,
                                   AppParams)
from repro.core.trace.generators import (app_kernels, kernel_params,
                                         make_trace)
from repro.core.trace.mix import APP_STRIDE, WorkloadMix
from repro.core.trace.serving import (TENANT_STRIDE, TENANTS,
                                      RequestStream, ServingMix,
                                      TenantParams, tenant_stream)

__all__ = [
    "APPS", "HIGH_LOCALITY", "LOW_LOCALITY", "AppParams",
    "app_kernels", "kernel_params", "make_trace",
    "APP_STRIDE", "WorkloadMix",
    "TENANT_STRIDE", "TENANTS", "RequestStream", "ServingMix",
    "TenantParams", "tenant_stream",
]
