"""Serving request streams: multi-tenant shared-prefix traffic.

The serving engine (``repro.serving.engine``) replays the paper's
inter-core-locality regime at LM-serving scale: requests arrive at
shards, their prompt prefixes hash to block chains, and shared system
prompts make the same chains recur across shards — the serving analog
of the inter-core data locality the aggregated tag array exploits.
This module generates that traffic as arrays, mirroring the
:class:`~repro.core.trace.mix.WorkloadMix` conventions:

* a calibrated :class:`TenantParams` table (:data:`TENANTS`) with
  per-tenant shared-prefix populations and arrival shaping (base rate,
  diurnal sinusoid, bursts);
* :class:`ServingMix` composes tenants by *superposition*: every mix
  slot generates its own full-grid arrival pattern and request content
  from an independent substream, and slots contending for the same
  (round, shard) admission slot are resolved by a rotating priority —
  so composition never changes what a tenant *would* send, only which
  offered requests win admission;
* **hash-space slicing** — slot ``s``'s block hashes live in
  ``[s * TENANT_STRIDE, (s+1) * TENANT_STRIDE)`` so tenants never
  falsely share blocks; slot 0 is offset-free, so a one-tenant mix
  composes to exactly the solo stream (tier-1 + hypothesis tested).

Uniqueness by construction: each slot's non-shared block hashes are
allocated from a per-slot counter (dense, collision-free) above a
small region reserved for the shared-prefix pools — random draws at
~1e7 blocks in an int31 space would collide often enough (birthday
bound) to fake measurable sharing.

The grid admits at most one request per shard per *sub-round* —
arrival ``rate`` is the per-shard admission probability, and
everything stays int32 (JAX default; the engine's tag arrays are
int32).

**Batched admission** (ROADMAP item 1 follow-on): a stream may carry
``slots = B > 1``, meaning each admission *round* spans ``B``
consecutive rows of the grid — ``B`` priority-ordered admission slots
per shard per round. The array layout is deliberately slot-major
sequential (row ``t*B + b`` is slot ``b`` of round ``t``), so the
engine's slot-order semantics — later slots see earlier slots'
replication inserts — coincide with plain row-order replay and the
oracle needs no change at all: iterating rows *is* slot-sequential
replay. ``slots`` therefore never changes any hit/probe/fetch counter;
it changes the *throughput model* (the engine charges one round of
``max`` latency per ``B`` admissions) and the admission capacity of
:meth:`ServingMix.make_stream` (up to ``B`` contending tenants win
per shard per round instead of one).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import numpy as np

#: Hash-space slice per mix slot. 2^26 leaves room for 31 slots below
#: int32; a power of two, so every power-of-two directory set count is
#: offset-invariant (slot offsets never change a block's set index
#: distribution).
TENANT_STRIDE = 1 << 26

#: Low region of each slot's slice reserved for shared-prefix pools;
#: the unique-block counter allocates above it.
PREFIX_SPACE = 1 << 16

_MAX_SLOTS = 16


@dataclasses.dataclass(frozen=True)
class TenantParams:
    """One tenant's traffic shape (the serving AppParams analog).

    ``n_prefixes`` shared system prompts of ``prefix_blocks`` blocks;
    ``shared_frac`` of requests start from one of them, the rest carry
    a fresh prefix. Every request appends ``unique_blocks`` fresh
    suffix blocks. ``rate`` is the base per-shard arrival probability
    per round, shaped by an optional diurnal sinusoid and bursts.
    """
    name: str
    n_prefixes: int = 12
    prefix_blocks: int = 8
    unique_blocks: int = 4
    shared_frac: float = 0.7
    rate: float = 0.9
    diurnal_amp: float = 0.0     # +/- fraction of rate over a period
    diurnal_period: int = 2048   # rounds per diurnal cycle
    burst_prob: float = 0.0      # per-round probability a burst starts
    burst_len: int = 64          # rounds a burst lasts
    burst_mult: float = 2.0      # rate multiplier inside a burst

    @property
    def n_blocks(self) -> int:
        return self.prefix_blocks + self.unique_blocks


#: Calibrated tenant table (the serving APPS analog): a high-sharing
#: steady chat tenant, a diurnal retrieval tenant with a wide prefix
#: population, and a low-sharing bursty batch tenant.
TENANTS = {
    "chat": TenantParams("chat", n_prefixes=8, prefix_blocks=8,
                         unique_blocks=4, shared_frac=0.85, rate=0.9),
    "rag": TenantParams("rag", n_prefixes=48, prefix_blocks=12,
                        unique_blocks=6, shared_frac=0.6, rate=0.7,
                        diurnal_amp=0.35, diurnal_period=4096),
    "batch": TenantParams("batch", n_prefixes=4, prefix_blocks=4,
                          unique_blocks=10, shared_frac=0.15, rate=0.35,
                          burst_prob=0.01, burst_len=96,
                          burst_mult=2.5),
}


def _resolve_tenant(t: Union[str, TenantParams]) -> TenantParams:
    if isinstance(t, TenantParams):
        return t
    try:
        return TENANTS[t]
    except KeyError:
        raise ValueError(
            f"unknown tenant {t!r}; known: {sorted(TENANTS)}") from None


@dataclasses.dataclass(frozen=True)
class RequestStream:
    """A (rounds, shards) request grid, the serving engine's input.

    ``valid[t, c]`` marks a request arriving at shard ``c`` in
    sub-round ``t``; its block-hash chain is
    ``hashes[t, c, :n_blocks[t, c]]`` (positive int32; lanes past
    ``n_blocks`` are 0, which never matches a directory tag) and
    ``tenant[t, c]`` its mix-slot id.

    ``slots`` (``B``) groups every ``B`` consecutive rows into one
    *admission round* of ``B`` priority-ordered slots per shard (see
    the module docstring); row order is slot order, so the arrays are
    layout-identical to their ``B=1`` slot-sequentialized replay.
    """
    valid: np.ndarray     # (T, C) bool
    hashes: np.ndarray    # (T, C, K) int32, >= 1 on valid block lanes
    n_blocks: np.ndarray  # (T, C) int32
    tenant: np.ndarray    # (T, C) int32 mix-slot id (0 where invalid)
    tenants: Tuple[str, ...] = ("tenant",)
    slots: int = 1        # admission slots per shard per round (B)

    def __post_init__(self):
        T, C, _ = self.hashes.shape
        assert self.valid.shape == (T, C), (self.valid.shape, (T, C))
        assert self.n_blocks.shape == (T, C)
        assert self.tenant.shape == (T, C)
        assert self.hashes.dtype == np.int32, self.hashes.dtype
        if not 1 <= self.slots <= _MAX_SLOTS:
            raise ValueError(
                f"slots {self.slots} outside [1, {_MAX_SLOTS}]")
        if T % self.slots:
            raise ValueError(
                f"{T} grid rows not divisible by slots={self.slots}")

    @property
    def rounds(self) -> int:
        """Grid rows (= sub-rounds; ``admission_rounds * slots``)."""
        return self.hashes.shape[0]

    @property
    def admission_rounds(self) -> int:
        """Engine scan steps: each admits up to ``slots`` per shard."""
        return self.hashes.shape[0] // self.slots

    @property
    def n_shards(self) -> int:
        return self.hashes.shape[1]

    @property
    def max_blocks(self) -> int:
        return self.hashes.shape[2]

    @property
    def n_requests(self) -> int:
        return int(self.valid.sum())

    @property
    def n_tenants(self) -> int:
        return len(self.tenants)

    def sequential(self) -> "RequestStream":
        """The same requests, one valid request per round.

        Round ``t*C + c`` carries only the original round-``t`` request
        of shard ``c``. With a single request in flight per round, the
        engine's round semantics degenerate to the sequential oracle's
        one-request-at-a-time semantics — the bit-exactness tests use
        this to compare against ``lookup_prefix``-style walks.
        """
        T, C, K = self.hashes.shape
        r = np.arange(C)
        valid = np.zeros((T, C, C), bool)
        hashes = np.zeros((T, C, C, K), np.int32)
        n_blocks = np.zeros((T, C, C), np.int32)
        tenant = np.zeros((T, C, C), np.int32)
        valid[:, r, r] = self.valid
        hashes[:, r, r, :] = self.hashes
        n_blocks[:, r, r] = self.n_blocks
        tenant[:, r, r] = self.tenant
        return RequestStream(valid=valid.reshape(T * C, C),
                             hashes=hashes.reshape(T * C, C, K),
                             n_blocks=n_blocks.reshape(T * C, C),
                             tenant=tenant.reshape(T * C, C),
                             tenants=self.tenants)

    def batched(self, slots: int) -> "RequestStream":
        """The same request population at ``slots`` admissions/round.

        Pure relabeling: the arrays are shared (slot-major layout means
        no data moves), only the round grouping changes. Requires the
        row count to divide evenly. Because the engine replays slots in
        sequential sub-rounds, every hit/probe/fetch counter is
        bit-identical across ``slots`` values — only the throughput
        model (rounds charged) changes. ``batched(1)`` is
        :meth:`slot_sequential`.
        """
        return dataclasses.replace(self, slots=slots)

    def slot_sequential(self) -> "RequestStream":
        """The ``B=1`` replay of this stream: one slot per round.

        Row order *is* slot order, so this is ``batched(1)`` — the
        canonical reference the batched-exactness property tests
        compare against.
        """
        return dataclasses.replace(self, slots=1)


def _arrival_rate(p: TenantParams, rounds: int,
                  rng: np.random.Generator) -> np.ndarray:
    """(T,) per-shard arrival probability after diurnal + burst shaping."""
    t = np.arange(rounds)
    rate = np.full(rounds, p.rate)
    if p.diurnal_amp:
        rate = rate * (1.0 + p.diurnal_amp
                       * np.sin(2.0 * np.pi * t / p.diurnal_period))
    if p.burst_prob:
        starts = rng.random(rounds) < p.burst_prob
        in_burst = np.convolve(starts, np.ones(p.burst_len))[:rounds] > 0
        rate = np.where(in_burst, rate * p.burst_mult, rate)
    return np.clip(rate, 0.0, 1.0)


def tenant_stream(tenant: Union[str, TenantParams], *, n_shards: int,
                  rounds: int, seed: int = 0,
                  slot: int = 0) -> RequestStream:
    """One tenant's solo stream (mix slot ``slot``; 0 = offset-free).

    The substream seed is keyed by ``(seed, slot)`` and the hash slice
    by ``slot`` alone, so a tenant's offered traffic is identical
    whether generated solo or as a component of any mix.
    """
    p = _resolve_tenant(tenant)
    if not 0 <= slot < _MAX_SLOTS:
        raise ValueError(f"slot {slot} outside [0, {_MAX_SLOTS})")
    rng = np.random.default_rng([int(seed), slot])
    T, C, K = rounds, n_shards, p.n_blocks
    base = slot * TENANT_STRIDE

    rate = _arrival_rate(p, T, rng)
    valid = rng.random((T, C)) < rate[:, None]

    # shared-prefix pools: distinct hashes in [1, PREFIX_SPACE)
    pool = (rng.choice(PREFIX_SPACE - 1,
                       size=p.n_prefixes * p.prefix_blocks,
                       replace=False).astype(np.int64) + 1
            ).reshape(p.n_prefixes, p.prefix_blocks)
    shared = rng.random((T, C)) < p.shared_frac
    pid = rng.integers(0, p.n_prefixes, size=(T, C))

    # fresh (never-shared) blocks come from a dense per-slot counter:
    # collision-free by construction, row-major over the request grid
    fresh_need = np.where(shared, p.unique_blocks, K) * valid
    flat = fresh_need.ravel()
    start = (np.cumsum(flat) - flat).reshape(T, C)
    total = int(flat.sum())
    if PREFIX_SPACE + total >= TENANT_STRIDE:
        raise ValueError(
            f"tenant {p.name!r} needs {total} fresh blocks over "
            f"{T} rounds x {C} shards — exceeds its hash slice "
            f"({TENANT_STRIDE - PREFIX_SPACE}); use fewer rounds")

    k = np.arange(K)
    fresh_idx = np.where(shared[..., None], k - p.prefix_blocks, k)
    hashes = PREFIX_SPACE + start[..., None].astype(np.int64) + fresh_idx
    hashes[:, :, :p.prefix_blocks] = np.where(
        shared[..., None], pool[pid], hashes[:, :, :p.prefix_blocks])
    hashes = (hashes + base) * valid[..., None]
    assert hashes.max(initial=0) < np.iinfo(np.int32).max

    return RequestStream(
        valid=valid,
        hashes=hashes.astype(np.int32),
        n_blocks=np.where(valid, K, 0).astype(np.int32),
        tenant=np.where(valid, slot, 0).astype(np.int32),
        tenants=(p.name,))


@dataclasses.dataclass(frozen=True)
class ServingMix:
    """A multi-tenant serving traffic spec (the WorkloadMix analog).

    ``tenants`` lists the co-served tenants (names from
    :data:`TENANTS` or explicit :class:`TenantParams`); each occurrence
    is an independent slot with its own rng substream and hash slice.
    """
    tenants: Tuple[Union[str, TenantParams], ...]
    name: Optional[str] = None

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("ServingMix needs at least one tenant")
        if len(self.tenants) > _MAX_SLOTS:
            raise ValueError(
                f"at most {_MAX_SLOTS} tenants per mix, got "
                f"{len(self.tenants)}")
        for t in self.tenants:
            _resolve_tenant(t)

    @property
    def n_tenants(self) -> int:
        return len(self.tenants)

    @property
    def mix_id(self) -> str:
        if self.name:
            return self.name
        return "+".join(_resolve_tenant(t).name for t in self.tenants)

    def component_streams(self, *, n_shards: int, rounds: int,
                          seed: int = 0) -> List[RequestStream]:
        """Per-slot solo streams, already hash-sliced by slot."""
        return [tenant_stream(t, n_shards=n_shards, rounds=rounds,
                              seed=seed, slot=s)
                for s, t in enumerate(self.tenants)]

    def make_stream(self, *, n_shards: int, rounds: int,
                    seed: int = 0, slots: int = 1) -> RequestStream:
        """Superimpose the component streams onto one request grid.

        Mix slots contending for the same (round, shard) admission are
        resolved by a rotating priority (mix slot ``s`` wins round
        ``t`` when it minimizes ``(s + t) % n_slots`` among the
        contenders), so no tenant is structurally starved. A one-tenant
        mix at ``slots=1`` is the solo stream, arrays bit-identical.

        ``slots = B > 1`` widens admission: the *first ``B``* priority-
        ordered contenders win (stable sort, so ``B=1`` picks exactly
        the old ``argmin`` winner), landing in slot order on ``B``
        consecutive grid rows per round (the batched layout of
        :class:`RequestStream`). Offered traffic is untouched —
        batching only admits requests that a ``B=1`` grid would have
        dropped.
        """
        if not 1 <= slots <= _MAX_SLOTS:
            raise ValueError(f"slots {slots} outside [1, {_MAX_SLOTS}]")
        comps = self.component_streams(n_shards=n_shards, rounds=rounds,
                                       seed=seed)
        names = tuple(_resolve_tenant(t).name for t in self.tenants)
        if len(comps) == 1 and slots == 1:
            return dataclasses.replace(comps[0], tenants=names)
        n = len(comps)
        B = slots
        K = max(c.max_blocks for c in comps)
        valid = np.stack([c.valid for c in comps])          # (n, T, C)
        hashes = np.zeros((n, rounds, n_shards, K), np.int32)
        for s, c in enumerate(comps):
            hashes[s, :, :, :c.max_blocks] = c.hashes
        n_blocks = np.stack([c.n_blocks for c in comps])
        tenant_id = np.arange(n)
        prio = (tenant_id[:, None] + np.arange(rounds)[None, :]) % n
        key = np.where(valid, prio[:, :, None], n)          # (n, T, C)
        # stable sort => slot b takes the b-th best contender, and the
        # b=0 row reproduces argmin's first-occurrence winner exactly;
        # slots beyond the contender count stay empty
        nb_take = min(B, n)
        order = np.argsort(key, axis=0, kind="stable")[:nb_take]
        bvalid = np.take_along_axis(key, order, axis=0) < n
        bh = np.take_along_axis(hashes, order[..., None], axis=0)
        bn = np.take_along_axis(n_blocks, order, axis=0) * bvalid
        bt = (order * bvalid).astype(np.int32)
        if nb_take < B:
            z = (B - nb_take, rounds, n_shards)
            bvalid = np.concatenate([bvalid, np.zeros(z, bool)])
            bh = np.concatenate([bh, np.zeros(z + (K,), np.int32)])
            bn = np.concatenate([bn, np.zeros(z, np.int32)])
            bt = np.concatenate([bt, np.zeros(z, np.int32)])

        def rows(a):  # (B, T, C, ...) -> (T*B, C, ...) slot-major rows
            return np.swapaxes(a, 0, 1).reshape(
                (rounds * B,) + a.shape[2:])

        return RequestStream(
            valid=rows(bvalid),
            hashes=rows(bh * bvalid[..., None]),
            n_blocks=rows(bn),
            tenant=rows(bt),
            tenants=names,
            slots=B)
