"""The calibrated application table (paper Section IV).

Real Rodinia/Tango/Polybench address traces are not available offline,
so each application is modeled as a parameterized request-stream
generator whose locality structure matches the paper's classification:
five high inter-core-locality apps (``b+tree, cfd, doitgen, conv3d,
SN``) and five low-locality apps (incl. ``HS3D, sradv1``). Parameters:

  shared_frac    probability a request targets the cluster-shared pool
                 (inter-core locality); the rest go to a per-core pool
  ws_shared      shared working set, in 128B lines (vs 512 lines/L1)
  ws_private     per-core private working set, in lines
  hot_frac/size  fraction of shared accesses hitting a small hot subset
                 (drives same-line / same-home contention)
  stream_frac    streaming (compulsory-miss) fraction
  coalesced      whether a load's m requests are consecutive lines
  write_frac     store fraction
  insn_per_req   amortized instructions per memory request (intensity)
  n_kernels      kernels per app (Fig. 9 per-kernel diversity)

Apps are *calibrated proxies*: EXPERIMENTS.md §Repro reports both the
paper-target numbers and sensitivity sweeps over these parameters. The
parameter values are load-bearing — golden tests pin the traces they
generate — so this module holds data only; the generators live in
:mod:`repro.core.trace.generators` and multi-app composition in
:mod:`repro.core.trace.mix`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class AppParams:
    name: str
    high_locality: bool
    shared_frac: float
    ws_shared: int
    ws_private: int
    hot_frac: float = 0.0
    hot_size: int = 64
    stream_frac: float = 0.05
    coalesced: float = 0.8
    write_frac: float = 0.08
    insn_per_req: float = 6.0
    n_kernels: int = 4
    rounds: int = 1536
    m: int = 4


APPS: Dict[str, AppParams] = {p.name: p for p in [
    # ---- high inter-core locality ----------------------------------------
    AppParams("b+tree", True, shared_frac=0.82, ws_shared=1024,
              ws_private=224, hot_frac=0.05, hot_size=48, coalesced=0.75,
              write_frac=0.04, insn_per_req=26.0, n_kernels=2, m=2),
    AppParams("cfd", True, shared_frac=0.86, ws_shared=1024,
              ws_private=288, hot_frac=0.05, hot_size=96, coalesced=0.85,
              write_frac=0.10, insn_per_req=26.0, n_kernels=5, m=2),
    AppParams("doitgen", True, shared_frac=0.72, ws_shared=1024,
              ws_private=320, hot_frac=0.75, hot_size=8, coalesced=0.85,
              write_frac=0.06, insn_per_req=10.0, n_kernels=3),
    AppParams("conv3d", True, shared_frac=0.68, ws_shared=1152,
              ws_private=352, hot_frac=0.50, hot_size=32, coalesced=0.85,
              write_frac=0.08, insn_per_req=11.0, n_kernels=5),
    AppParams("SN", True, shared_frac=0.76, ws_shared=1344,
              ws_private=288, hot_frac=0.45, hot_size=48, coalesced=0.8,
              write_frac=0.05, insn_per_req=13.0, n_kernels=8),
    # ---- low inter-core locality ------------------------------------------
    AppParams("HS3D", False, shared_frac=0.10, ws_shared=512,
              ws_private=448, stream_frac=0.25, coalesced=0.9,
              write_frac=0.15, insn_per_req=7.0, n_kernels=6),
    AppParams("sradv1", False, shared_frac=0.08, ws_shared=384,
              ws_private=512, stream_frac=0.20, coalesced=0.9,
              write_frac=0.18, insn_per_req=6.0, n_kernels=15),
    AppParams("gaussian", False, shared_frac=0.12, ws_shared=448,
              ws_private=416, stream_frac=0.15, coalesced=0.85,
              write_frac=0.12, insn_per_req=8.0, n_kernels=3),
    AppParams("lud", False, shared_frac=0.14, ws_shared=512,
              ws_private=480, stream_frac=0.10, coalesced=0.8,
              write_frac=0.10, insn_per_req=7.0, n_kernels=4),
    AppParams("nw", False, shared_frac=0.06, ws_shared=320,
              ws_private=544, stream_frac=0.30, coalesced=0.75,
              write_frac=0.14, insn_per_req=6.0, n_kernels=2),
]}

HIGH_LOCALITY = [n for n, p in APPS.items() if p.high_locality]
LOW_LOCALITY = [n for n, p in APPS.items() if not p.high_locality]
