"""Synthetic trace generators over the calibrated app table.

:func:`make_trace` turns one app's :class:`~repro.core.trace.apps.
AppParams` into a :class:`~repro.core.simulator.Trace` for all cores:
a per-(round, core) classification into shared / streaming / private
request pools, coalescing of each load's ``m`` requests, and an int32
narrowing guard on the generated line addresses. Multi-app composition
(address-space slicing, core assignment, phase stagger) lives in
:mod:`repro.core.trace.mix` on top of these generators.

Kernel-0 convention: **kernel 0 is the canonical calibration kernel**
— it is generated from the app's raw calibrated parameters, while
kernels ``1..n_kernels-1`` draw deterministic per-kernel jitter around
them (Fig. 9 per-kernel diversity). :func:`kernel_params` is the single
place that rule lives; a regression test pins it so the asymmetry can
never silently flip (pre-PR-4 the rule existed only as a truthiness
accident, ``if kernel``).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import List

import numpy as np

from repro.core.simulator import Trace
from repro.core.trace.apps import APPS, AppParams

#: Disjoint address regions (line numbers) within one app's slice.
_SHARED_BASE = 0
_PRIVATE_BASE = 1 << 20
_STREAM_BASE = 1 << 26


def _stable_seed(*parts) -> int:
    return zlib.crc32("|".join(str(p) for p in parts).encode()) & 0x7FFFFFFF


def _require_int32(addr: np.ndarray) -> np.ndarray:
    """Narrow int64 addresses to the simulator's int32, refusing to wrap.

    The streaming region grows monotonically from ``_STREAM_BASE`` and
    multi-app mixes slice the address space per app; very long traces
    (or too many co-scheduled apps) could silently overflow into
    negative line numbers on ``astype(np.int32)``, corrupting set
    hashing and region disjointness.
    """
    lo, hi = int(addr.min()), int(addr.max())
    info = np.iinfo(np.int32)
    if lo < 0 or hi > info.max:
        raise ValueError(
            f"trace addresses span [{lo}, {hi}], outside int32 "
            f"[0, {info.max}]; shrink rounds/working sets/app count or "
            "widen the simulator address type")
    return addr.astype(np.int32)


def _jittered_params(app: AppParams, kernel: int) -> AppParams:
    """Deterministic per-kernel jitter around the app's parameters."""
    rng = np.random.default_rng(_stable_seed(app.name, kernel))
    scale = lambda lo, hi: float(rng.uniform(lo, hi))
    return dataclasses.replace(
        app,
        shared_frac=float(np.clip(app.shared_frac * scale(0.6, 1.25), 0, .95)),
        ws_shared=max(64, int(app.ws_shared * scale(0.5, 1.6))),
        ws_private=max(64, int(app.ws_private * scale(0.7, 1.3))),
        hot_frac=float(np.clip(app.hot_frac * scale(0.5, 1.5), 0, 0.8)),
        stream_frac=float(np.clip(app.stream_frac * scale(0.5, 1.8), 0, .5)),
        insn_per_req=app.insn_per_req * scale(0.8, 1.25),
    )


def kernel_params(app: AppParams, kernel: int) -> AppParams:
    """The effective parameters of one kernel of ``app``.

    Kernel 0 returns ``app`` itself — the canonical calibration kernel,
    generated from the raw calibrated parameters so calibration scripts,
    goldens, and mixes have a jitter-free anchor. Kernels ``>= 1`` get
    deterministic jitter (:func:`_jittered_params`). Negative kernels
    are rejected rather than silently treated as jittered.
    """
    if kernel < 0:
        raise ValueError(f"kernel must be >= 0, got {kernel}")
    return app if kernel == 0 else _jittered_params(app, kernel)


#: Backwards-compatible alias (pre-trace-package name).
_kernel_params = _jittered_params


def make_trace(app: AppParams, *, n_cores: int = 30, kernel: int = 0,
               seed: int = 0) -> Trace:
    """Generate one kernel's request trace for all cores."""
    p = kernel_params(app, kernel)
    rng = np.random.default_rng(_stable_seed(app.name, kernel, seed))
    T, C, m = p.rounds, n_cores, p.m

    # Per-(round, core) load classification.
    u = rng.random((T, C))
    is_shared = u < p.shared_frac
    is_stream = (u >= p.shared_frac) & (u < p.shared_frac + p.stream_frac)

    base = np.empty((T, C), np.int64)
    # shared pool (common to all cores in a cluster -> inter-core locality)
    hot = rng.random((T, C)) < p.hot_frac
    shared_addr = np.where(
        hot,
        rng.integers(0, p.hot_size, (T, C)),
        rng.integers(0, p.ws_shared, (T, C)))
    base[is_shared] = (_SHARED_BASE + shared_addr)[is_shared]
    # streaming: monotonically advancing per core (compulsory misses)
    stream = (_STREAM_BASE + np.arange(C)[None, :] * (1 << 16)
              + np.cumsum(np.ones((T, C), np.int64), axis=0) * m)
    base[is_stream] = stream[is_stream]
    # private pool
    priv = (_PRIVATE_BASE + np.arange(C)[None, :] * (1 << 14)
            + rng.integers(0, p.ws_private, (T, C)))
    rest = ~(is_shared | is_stream)
    base[rest] = priv[rest]

    # Coalescing: a load's m requests are consecutive lines (regular apps)
    # or independent re-samples from the same pool (irregular apps).
    coal = rng.random((T, C, 1)) < p.coalesced
    consec = base[:, :, None] + np.arange(m)[None, None, :]
    hot_s = rng.random((T, C, m)) < p.hot_frac
    resample_shared = _SHARED_BASE + np.where(
        hot_s,
        rng.integers(0, p.hot_size, (T, C, m)),
        rng.integers(0, p.ws_shared, (T, C, m)))
    resample_priv = (_PRIVATE_BASE + np.arange(C)[None, :, None] * (1 << 14)
                     + rng.integers(0, p.ws_private, (T, C, m)))
    scattered = np.where(is_shared[:, :, None], resample_shared,
                         resample_priv)
    scattered = np.where(is_stream[:, :, None], consec, scattered)
    addr = np.where(coal, consec, scattered).astype(np.int64)

    is_write = rng.random((T, C, m)) < p.write_frac
    return Trace(addr=_require_int32(addr), is_write=is_write,
                 insn_per_req=p.insn_per_req)


def app_kernels(name: str) -> List[int]:
    return list(range(APPS[name].n_kernels))
