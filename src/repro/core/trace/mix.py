"""Multi-tenant workload composition: co-scheduled apps on one GPU.

The paper evaluates one application at a time, but real GPUs
co-schedule kernels from multiple applications on the same cluster, and
inter-application interference in the shared memory system is exactly
where contention-mitigation policies diverge most (MASK, arXiv
1708.04911; shared-resource survey, arXiv 1803.06958).
:class:`WorkloadMix` composes several calibrated apps into one
:class:`~repro.core.simulator.Trace`:

* **core assignment** — ``partitioned`` (contiguous blocks),
  ``interleaved`` (round-robin dealing), or asymmetric ``shares``
  (explicit cores per app);
* **address-space slicing** — each mix slot's addresses are offset by
  ``slot * APP_STRIDE`` so co-runners never falsely share lines; the
  stride is a multiple of every power-of-two L1 set count, so each
  app's set mapping (and thus its solo cache behavior) is preserved;
* **phase stagger** — optionally each slot's rounds are rotated by
  ``slot * phase_rounds``, modeling kernels that don't launch in
  lock-step;
* **shape coercion** — components are re-generated at a common
  ``(rounds, m)`` (the min rounds / max m over the mix unless pinned),
  since one composed trace has one shape;
* **attribution channel** — the composed trace carries
  ``core_app`` (app id per core) and a per-core ``insn_per_req``
  vector, which the simulator turns into a per-app
  :class:`~repro.core.simulator.AppStats` block.

The *same* sliced, staggered, full-machine component traces double as
the solo baselines (:meth:`WorkloadMix.component_traces`), so the
slowdown each app sees in the mix is interference, not an address-map
artifact. A mix of a single app composes to exactly its solo trace —
``simulate`` over the two is bit-identical (tier-1 test).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import numpy as np

from repro.core.simulator import Trace
from repro.core.trace.apps import APPS, AppParams
from repro.core.trace.generators import _require_int32, make_trace

#: Address-space stride between mix slots (line numbers). A power of
#: two: every app's L1-set mapping is offset-invariant, and the int32
#: guard in ``_require_int32`` caps a mix at 16 slots rather than
#: letting slot 16 wrap into slot 0's region.
APP_STRIDE = 1 << 27

_LAYOUTS = ("partitioned", "interleaved")


def _resolve_app(app: Union[str, AppParams]) -> AppParams:
    if isinstance(app, AppParams):
        return app
    try:
        return APPS[app]
    except KeyError:
        raise ValueError(
            f"unknown app {app!r}; known: {sorted(APPS)}") from None


@dataclasses.dataclass(frozen=True)
class WorkloadMix:
    """A co-scheduling spec: which apps, on which cores, in which phase.

    ``apps`` lists the co-runners (names from the calibrated table, or
    explicit :class:`AppParams`); the same app may appear twice — each
    occurrence is an independent *slot* with its own seed and address
    slice. ``shares`` gives cores per slot (defaults to an equal split
    with the remainder on the earliest slots). ``kernels`` is one
    kernel index for every slot or a per-slot tuple. ``rounds`` pins
    the composed trace length (default: the shortest component).
    """
    apps: Tuple[Union[str, AppParams], ...]
    shares: Optional[Tuple[int, ...]] = None
    layout: str = "partitioned"
    kernels: Union[int, Tuple[int, ...]] = 0
    phase_rounds: int = 0
    rounds: Optional[int] = None
    name: Optional[str] = None

    def __post_init__(self):
        if not self.apps:
            raise ValueError("WorkloadMix needs at least one app")
        if self.layout not in _LAYOUTS:
            raise ValueError(
                f"layout must be one of {_LAYOUTS}, got {self.layout!r}")
        if self.shares is not None and len(self.shares) != len(self.apps):
            raise ValueError(
                f"shares {self.shares} must give one core count per app "
                f"({len(self.apps)} apps)")
        if isinstance(self.kernels, tuple) and \
                len(self.kernels) != len(self.apps):
            raise ValueError(
                f"kernels tuple {self.kernels} must give one kernel per "
                f"app ({len(self.apps)} apps)")
        for app in self.apps:
            _resolve_app(app)

    # ------------------------------------------------------------------
    @property
    def n_apps(self) -> int:
        return len(self.apps)

    @property
    def mix_id(self) -> str:
        """A stable human-readable id (report cells, result keys)."""
        if self.name:
            return self.name
        names = "+".join(_resolve_app(a).name for a in self.apps)
        tags = []
        if self.shares is not None:
            tags.append("@" + ",".join(str(s) for s in self.shares))
        if self.layout != "partitioned":
            tags.append("|" + self.layout)
        if self.phase_rounds:
            tags.append(f"|ph{self.phase_rounds}")
        return names + "".join(tags)

    def slot_kernel(self, slot: int) -> int:
        return self.kernels[slot] if isinstance(self.kernels, tuple) \
            else int(self.kernels)

    # ------------------------------------------------------------------
    def resolve_shares(self, n_cores: int) -> Tuple[int, ...]:
        """Cores per slot; equal split (remainder to early slots) by
        default."""
        A = self.n_apps
        if self.shares is None:
            base, rem = divmod(n_cores, A)
            shares = tuple(base + (1 if i < rem else 0) for i in range(A))
        else:
            shares = tuple(int(s) for s in self.shares)
        if any(s < 1 for s in shares):
            raise ValueError(
                f"every app needs >= 1 core, got shares {shares} over "
                f"{n_cores} cores")
        if sum(shares) != n_cores:
            raise ValueError(
                f"shares {shares} must sum to n_cores={n_cores}")
        return shares

    def core_assignment(self, n_cores: int) -> np.ndarray:
        """(C,) int32 slot id per core under the mix's layout."""
        shares = self.resolve_shares(n_cores)
        if self.layout == "partitioned":
            return np.repeat(np.arange(self.n_apps), shares) \
                     .astype(np.int32)
        # interleaved: deal slots round-robin until every share is spent
        out: List[int] = []
        remaining = list(shares)
        while len(out) < n_cores:
            for slot in range(self.n_apps):
                if remaining[slot]:
                    remaining[slot] -= 1
                    out.append(slot)
        return np.asarray(out, np.int32)

    def component_params(self) -> List[AppParams]:
        """Per-slot params coerced to the common composed (rounds, m)."""
        params = [_resolve_app(a) for a in self.apps]
        T = self.rounds if self.rounds is not None \
            else min(p.rounds for p in params)
        m = max(p.m for p in params)
        return [dataclasses.replace(p, rounds=T, m=m) for p in params]

    def component_traces(self, n_cores: int = 30, *,
                         seed: int = 0) -> List[Trace]:
        """Per-slot *solo* traces on the full machine.

        Each slot's trace already carries its mix-slot address offset
        and phase rotation, so a solo run of a component and the
        composed mix expose every core of that app to byte-identical
        addresses — slowdowns measured against these baselines are pure
        interference. Slot 0 is offset- and rotation-free: a one-app
        mix composes to exactly its solo trace.
        """
        comps = []
        for slot, p in enumerate(self.component_params()):
            tr = make_trace(p, n_cores=n_cores,
                            kernel=self.slot_kernel(slot),
                            seed=seed + slot)
            shift = (slot * self.phase_rounds) % p.rounds \
                if self.phase_rounds else 0
            if slot == 0 and not shift:
                comps.append(tr)      # bit-identical to make_trace
                continue
            addr = tr.addr.astype(np.int64) + slot * APP_STRIDE
            is_write = tr.is_write
            if shift:
                addr = np.roll(addr, shift, axis=0)
                is_write = np.roll(is_write, shift, axis=0)
            comps.append(Trace(addr=_require_int32(addr),
                               is_write=is_write,
                               insn_per_req=tr.insn_per_req))
        return comps

    def compose(self, n_cores: int = 30, *, seed: int = 0) -> Trace:
        """The composed multi-tenant trace (one shape, one ``Trace``)."""
        assign = self.core_assignment(n_cores)
        comps = self.component_traces(n_cores, seed=seed)
        T, _, m = comps[0].addr.shape
        addr = np.empty((T, n_cores, m), np.int32)
        is_write = np.empty((T, n_cores, m), bool)
        insn = np.empty((n_cores,), np.float32)
        for slot, tr in enumerate(comps):
            cols = assign == slot
            addr[:, cols, :] = tr.addr[:, cols, :]
            is_write[:, cols, :] = tr.is_write[:, cols, :]
            insn[cols] = tr.insn_per_req
        return Trace(addr=addr, is_write=is_write, insn_per_req=insn,
                     core_app=assign)
