"""Ring interconnect: hop-distance latency + per-link flit accounting.

Each cluster's cores sit on a bidirectional ring (one position per
cluster slot); a remote transfer from serving core to requesting core
travels the shorter arc, paying ``ring_hop`` cycles per hop, and its
flits occupy every link along that arc. Link ``c * G + p`` connects
cluster ``c``'s positions ``p`` and ``(p+1) % G``.

Delay is the pure hop latency; the contention signal is *occupancy*:
the busiest link on a request's path serializes the round's flit-hops
at ``port_rate = noc_bw / cluster_size`` flits/cycle, a throughput
bound warps cannot hide. Probe-style traffic whose serving core equals
the requester (``src == dst``) has hop distance zero — it rides the
dedicated probe channels the architecture policies already price in —
so the ring specifically penalizes *data* movement between distant
slots, which is exactly the traffic ATA's tag-side filtering avoids
speculating on.

Everything injected is delivered within the round (the ring models
latency/hotspots, not admission control — the ``crossbar`` models
queue backpressure), so conservation holds with an always-empty
carried queue. ``link_flits`` counts flit-*hops* per link (the
utilization/hotspot metric); the scalar ``injected``/``delivered``
counters stay at injection granularity like every other model.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.noc.base import (NocModel, NocState, NocTraffic, NocTransit,
                                 port_rate)


@dataclasses.dataclass(frozen=True)
class RingNoc(NocModel):
    name: str = "ring"

    def n_links(self, geom) -> int:
        return geom.n_cores          # G links per cluster ring

    def transit(self, geom, state: NocState,
                traffic: NocTraffic) -> NocTransit:
        L = state["queue"].shape[0]
        G = geom.cluster_size
        rate = port_rate(geom)
        use = traffic.crossing       # src == dst never enters the network
        flits = jnp.where(use, traffic.flits, 0.0)

        s = traffic.src % G                       # (R,) slot positions
        d = traffic.dst % G
        fwd = (d - s) % G
        bwd = (s - d) % G
        go_fwd = fwd <= bwd
        dist = jnp.minimum(fwd, bwd).astype(jnp.float32)

        # Links on the shorter arc, within the request's own cluster:
        # forward from s uses ring links s..s+fwd-1, backward uses
        # s-1..s-bwd (all mod G), offset into the cluster's link block.
        lpos = jnp.arange(G, dtype=jnp.int32)[None, :]        # (1, G)
        off_f = (lpos - s[:, None]) % G
        off_b = (s[:, None] - 1 - lpos) % G
        on_path = jnp.where(go_fwd[:, None], off_f < fwd[:, None],
                            off_b < bwd[:, None])             # (R, G)
        link = traffic.cluster[:, None] * G + lpos            # (R, G)
        hop_flits = jnp.where(on_path & use[:, None],
                              flits[:, None], 0.0)
        link_load = jnp.zeros((L,), jnp.float32).at[link].add(hop_flits)

        # Bottleneck serialization: the busiest link on my path this
        # round bounds my cluster-ring throughput.
        path_load = jnp.max(
            jnp.where(on_path, link_load[link], 0.0), axis=1)
        delay = jnp.where(use, dist * geom.ring_hop, 0.0)
        occupancy = jnp.where(use, path_load / rate, 0.0)

        total = jnp.sum(flits)
        new_state = dict(
            state,
            link_flits=state["link_flits"] + link_load,
            link_busy=state["link_busy"] + link_load / rate,
        )
        new_state = self._count(new_state, traffic, delay,
                                injected=total, delivered=total)
        return NocTransit(state=new_state, delay=delay,
                          occupancy=occupancy)
