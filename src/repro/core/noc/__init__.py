"""Pluggable interconnect (NoC) models for the cache-hierarchy simulator.

Public API:
  NocModel, NocTraffic, NocTransit, init_noc_state — the model
      interface + carried-state convention (base.py)
  register_noc / get_noc / registered_nocs — the model registry
  PAPER_NOCS — the topology comparison set the benchmarks sweep

Three models register on import:

  ideal    : infinite bandwidth, zero latency — bit-exact with the
             pre-NoC simulator (the default everywhere)
  crossbar : per-port arbitration with finite injection queues whose
             occupancy carries across rounds (real backpressure)
  ring     : hop-distance latency from cluster positions plus
             per-link flit accounting (hotspots)

External code adds more with::

    from repro.core.noc import NocModel, register_noc

    @dataclasses.dataclass(frozen=True)
    class MyNoc(NocModel):
        name: str = "mine"
        def transit(self, geom, state, traffic): ...

    register_noc(MyNoc())

after which ``simulate(arch, trace, noc="mine")`` just works, and
``SweepGrid(..., nocs=("ideal", "mine"))`` stacks it as a grid axis.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.noc.base import (NocModel, NocState, NocTraffic, NocTransit,
                                 init_noc_state, port_rate)
from repro.core.noc.ideal import IdealNoc
from repro.core.noc.crossbar import CrossbarNoc
from repro.core.noc.ring import RingNoc

#: The topology comparison set the benchmarks sweep (fig_noc_topology,
#: the sensitivity report's ``noc`` section).
PAPER_NOCS: Tuple[str, ...] = ("ideal", "crossbar", "ring")

_REGISTRY: Dict[str, NocModel] = {}


def register_noc(model: NocModel, *, overwrite: bool = False) -> NocModel:
    """Add a model to the registry under ``model.name``."""
    if not isinstance(model, NocModel):
        raise TypeError(f"expected a NocModel, got {type(model)!r}")
    if model.name in _REGISTRY and not overwrite:
        raise ValueError(f"NoC model {model.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[model.name] = model
    return model


def get_noc(name: str) -> NocModel:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown NoC model {name!r}; registered: "
            f"{registered_nocs()}") from None


def registered_nocs() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


register_noc(IdealNoc())
register_noc(CrossbarNoc())
register_noc(RingNoc())

__all__ = [
    "NocModel", "NocState", "NocTraffic", "NocTransit", "init_noc_state",
    "port_rate", "IdealNoc", "CrossbarNoc", "RingNoc", "PAPER_NOCS",
    "register_noc", "get_noc", "registered_nocs",
]
