"""Ideal interconnect: infinite bandwidth, zero added latency.

This is exactly the pre-NoC simulator's (implicit) interconnect — the
architecture policies' own memoryless per-round contention is the whole
model. ``transit`` adds zero delay and zero occupancy (``x + 0.0`` and
``max(x, 0.0)`` are bit-exact for the non-negative timing values, so
``noc="ideal"`` reproduces the pre-NoC simulator bit-for-bit; tier-1
goldens pin this) and only folds the flit totals into the conservation
counters: everything injected is delivered in the same round, nothing
queues.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.noc.base import NocModel, NocState, NocTraffic, NocTransit


@dataclasses.dataclass(frozen=True)
class IdealNoc(NocModel):
    name: str = "ideal"

    def transit(self, geom, state: NocState,
                traffic: NocTraffic) -> NocTransit:
        zeros = jnp.zeros_like(traffic.flits)
        total = jnp.sum(jnp.where(traffic.crossing, traffic.flits, 0.0))
        state = self._count(state, traffic, zeros,
                            injected=total, delivered=total)
        return NocTransit(state=state, delay=zeros, occupancy=zeros)
