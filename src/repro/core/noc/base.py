"""Interconnect-model interface for the cache-hierarchy simulator.

The L1-complex interconnect — the network that carries remote-*probe*
and remote-*data* flits between the caches of a cluster — is the
resource the paper's whole argument is about: ATA wins by *filtering*
that traffic. ``repro.core.noc`` makes the interconnect a pluggable
axis, mirroring the ``repro.core.arch`` policy registry:

    L1 policy stage -> L2 stage -> fill stage -> NoC stage -> timing

A :class:`NocModel` receives one round's NoC traffic (one entry per
request: serving core, requesting core, flits) plus the NoC state
carried across rounds in the scan carry, and returns extra per-request
delay, extra serial-resource occupancy, and the updated state. The
memoryless per-round contention already inside the architecture
policies (``group_rank`` over ports) stays where it is — a NoC model
adds the *topology* effects on top: cross-round queue backpressure
(``crossbar``), hop-distance latency and per-link hotspots (``ring``),
or nothing at all (``ideal``, bit-exact with the pre-NoC simulator).

State convention (the TagState-extension convention, applied again):
:func:`init_noc_state` always creates the same pytree keys —

    queue      : (L,) float32  flits waiting per injection port
    link_flits : (L,) float32  cumulative flits forwarded per link/port
    link_busy  : (L,) float32  cumulative service cycles per link/port
    injected   : () float32    cumulative flits entering the NoC
    delivered  : () float32    cumulative flits leaving the NoC
    delay_sum  : () float32    summed per-request NoC delay
    delay_n    : () float32    requests that crossed the NoC

— with ``L`` sized by the *maximum* :meth:`NocModel.n_links` over the
models compiled together (``simulator._noc_state``), so every model in
a stacked executable carries one pytree structure and ``lax.switch``
branches line up. A model that ignores a field must thread it through
unchanged; ``ideal`` declares ``n_links = 0`` and only counts flits.

Conservation invariant (tier-1 tested for every registered model):
``injected == delivered + queue.sum()`` after every round and at the
end of the simulation — backpressure may *defer* flits, never lose
them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax.numpy as jnp

NocState = Dict[str, jnp.ndarray]


class NocTraffic(NamedTuple):
    """One round's L1-complex NoC traffic, one entry per request.

    ``src`` is the core whose cache serves the request (`==` ``dst``
    when nothing crosses, or for source-side probe traffic), ``dst``
    the requesting core. ``flits`` counts the request's probe + data
    flits on this network (L2/write-back traffic rides the separate
    memory-side network and is *not* routed here); ``mask`` selects the
    requests whose critical path includes the NoC.
    """
    src: jnp.ndarray      # (R,) int32 serving core
    dst: jnp.ndarray      # (R,) int32 requesting core
    cluster: jnp.ndarray  # (R,) int32 cluster of the requesting core
    flits: jnp.ndarray    # (R,) float32 flits injected by this request
    mask: jnp.ndarray     # (R,) bool request traverses the NoC

    @property
    def crossing(self) -> jnp.ndarray:
        """(R,) bool — entries that actually enter the network.

        The uniform rule every model applies: traffic must be masked,
        carry flits, and move between *distinct* cores. ``src == dst``
        traffic never leaves the core, so no model may charge it port
        bandwidth, hops, or queue delay — pricing it in one topology
        but not another would skew cross-model comparisons.
        """
        return self.mask & (self.flits > 0) & (self.src != self.dst)


class NocTransit(NamedTuple):
    """What the NoC did with one round's traffic."""
    state: NocState       # updated carried state
    delay: jnp.ndarray    # (R,) float32 extra cycles on the request path
    occupancy: jnp.ndarray  # (R,) float32 extra serial-resource busy time


def init_noc_state(n_links: int) -> NocState:
    """The carried NoC state pytree (uniform keys; see module docstring)."""
    f = jnp.float32
    return {
        "queue": jnp.zeros((n_links,), f),
        "link_flits": jnp.zeros((n_links,), f),
        "link_busy": jnp.zeros((n_links,), f),
        "injected": f(0.0),
        "delivered": f(0.0),
        "delay_sum": f(0.0),
        "delay_n": f(0.0),
    }


@dataclasses.dataclass(frozen=True)
class NocModel:
    """A pluggable interconnect model.

    Subclasses implement :meth:`transit` and declare via
    :meth:`n_links` how many link/port lanes of carried state they
    need (given the geometry). The simulator sizes the state by the
    maximum over the stacked group, exactly like the TagState
    extensions, so models sharing a :attr:`stack_key` compile into one
    executable with the active model selected by a traced index.
    """
    name: str

    @property
    def stack_key(self) -> str:
        """Dataflow-group tag for sweep stacking.

        Unlike architecture policies — whose round dataflow is
        arbitrary — every NoC model carries the *same* state pytree by
        construction (:func:`init_noc_state`), so the built-ins all
        share the ``"noc"`` family and any grid mixing them compiles
        one executable per architecture family. Override with your own
        name only if your model cannot share the uniform state
        (``SweepGrid._validate`` rejects mismatched stacks either way).
        """
        return "noc"

    def n_links(self, geom) -> int:
        """Link/port lanes of carried state this model uses (0 = none)."""
        return 0

    def transit(self, geom, state: NocState,
                traffic: NocTraffic) -> NocTransit:
        raise NotImplementedError

    # ------------------------------------------------------------------
    # shared accounting helpers
    # ------------------------------------------------------------------
    @staticmethod
    def _count(state: NocState, traffic: NocTraffic,
               delay: jnp.ndarray, *, injected: jnp.ndarray,
               delivered: jnp.ndarray) -> NocState:
        """Fold one round's conservation + delay accounting into state."""
        crossed = traffic.crossing
        f32 = jnp.float32
        return dict(
            state,
            injected=state["injected"] + injected,
            delivered=state["delivered"] + delivered,
            delay_sum=state["delay_sum"]
            + jnp.sum(jnp.where(crossed, delay, 0.0)),
            delay_n=state["delay_n"] + jnp.sum(crossed).astype(f32),
        )


def port_rate(geom) -> jnp.ndarray:
    """Per-port forwarding rate (flits/cycle): the cluster's probe
    network bandwidth shared across its cores' ports."""
    return geom.noc_bw / geom.cluster_size
