"""Crossbar interconnect: per-port arbitration with carried backpressure.

Each core's remote-data port is a crossbar output with a finite
injection queue. Flits a request moves across the NoC arrive at the
*serving* core's port; the port forwards at
``port_rate = noc_bw / cluster_size`` flits/cycle for a drain window of
``noc_drain`` cycles per round. What the window cannot forward stays in
the port queue **across rounds** — real backpressure, unlike the
memoryless per-round ranks inside the architecture policies — and
occupancy beyond the ``noc_queue`` capacity stalls the port's sources
for the overflow's drain time on top.

Per-request delay =

    standing backlog ahead of me   queue[port] / rate
  + same-round flits ahead of me   group_prefix_sum(...) / rate
  + backpressure stall             overflow[port] / rate

and the port's whole supply is a serial-resource occupancy bound the
warp scheduler cannot hide. Conservation — ``injected == delivered +
queued`` — holds round by round; it is bit-exact while the per-round
drain budget ``rate * noc_drain`` is an integral flit count, and holds
to float32 accumulation error otherwise (fractional ``sent`` amounts;
see ``NocStats``).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.contention import group_prefix_sum
from repro.core.noc.base import (NocModel, NocState, NocTraffic, NocTransit,
                                 port_rate)


@dataclasses.dataclass(frozen=True)
class CrossbarNoc(NocModel):
    name: str = "crossbar"

    def n_links(self, geom) -> int:
        return geom.n_cores          # one injection port per core

    def transit(self, geom, state: NocState,
                traffic: NocTraffic) -> NocTransit:
        L = state["queue"].shape[0]  # >= n_links(geom) when stacked
        rate = port_rate(geom)
        use = traffic.crossing       # src == dst never enters the network
        flits = jnp.where(use, traffic.flits, 0.0)
        port = traffic.src

        arrivals = jnp.zeros((L,), jnp.float32).at[port].add(flits)
        supply = state["queue"] + arrivals
        avail = rate * geom.noc_drain
        sent = jnp.minimum(supply, avail)
        queued = supply - sent
        overflow = jnp.maximum(queued - geom.noc_queue, 0.0)

        ahead, _ = group_prefix_sum(port, flits, use, L)
        delay = jnp.where(
            use,
            (state["queue"][port] + ahead + overflow[port]) / rate,
            0.0)
        occupancy = jnp.where(use, supply[port] / rate, 0.0)

        new_state = dict(
            state,
            queue=queued,
            link_flits=state["link_flits"] + sent,
            link_busy=state["link_busy"] + sent / rate,
        )
        new_state = self._count(new_state, traffic, delay,
                                injected=jnp.sum(arrivals),
                                delivered=jnp.sum(sent))
        return NocTransit(state=new_state, delay=delay,
                          occupancy=occupancy)
