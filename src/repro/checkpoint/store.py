"""Sharded checkpointing with async save, restart, and elastic re-mesh.

Layout (one directory per step):
    ckpt_dir/step_000100/
        manifest.json        {step, leaf paths, shapes, dtypes}
        <escaped-path>.npy   one file per pytree leaf

Design points for the 1000-node regime (documented here, exercised in
tests at host scale):
  * every leaf is written independently -> per-host shard writing maps
    onto jax.Array addressable shards (here: single-host full arrays);
  * writes go to a temp dir + atomic rename, so a node failure mid-save
    never corrupts the latest checkpoint (restore scans for the newest
    *complete* manifest);
  * async save: the device->host copy is synchronous (cheap), the disk
    write happens on a worker thread so the train loop keeps stepping;
  * elastic re-mesh: restore() takes target shardings — any mesh shape
    can load any checkpoint (jax.device_put reshards), so a job can
    restart on a different pod slice after failures.
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

#: numpy can't round-trip ml_dtypes through .npy; store as uint views.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _esc(path: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "~", path)


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        spath = "/".join(p.key if hasattr(p, "key") else str(p.idx)
                         for p in path)
        out[spath] = leaf
    return out


class CheckpointStore:
    def __init__(self, directory):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, wait: bool = False):
        """Snapshot to host memory now; write to disk on a worker thread."""
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=True)
        self._thread.start()
        if wait:
            self.wait()

    def _write(self, step: int, host: Dict[str, np.ndarray]):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": {}}
        for path, arr in host.items():
            fname = _esc(path) + ".npy"
            dtype = str(arr.dtype)
            if dtype in _EXOTIC:
                np.save(tmp / fname, arr.view(_EXOTIC[dtype][1]))
            else:
                np.save(tmp / fname, arr)
            manifest["leaves"][path] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": dtype}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    # -- restore ------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in self.dir.glob("step_*"):
            if (d / "manifest.json").exists():
                steps.append(int(d.name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None):
        """Load into the structure of ``tree_like``; optionally reshard.

        ``shardings`` may target a *different* mesh than the checkpoint
        was written from (elastic re-mesh).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        want = _flatten(tree_like)
        sh = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for path in want:
            meta = manifest["leaves"].get(path)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {path}")
            arr = np.load(d / meta["file"])
            if meta["dtype"] in _EXOTIC:
                arr = arr.view(_EXOTIC[meta["dtype"]][0])
            if path in sh:
                loaded[path] = jax.device_put(arr, sh[path])
            else:
                loaded[path] = jax.numpy.asarray(arr)
        flat = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = []
        for p, _ in flat[0]:
            spath = "/".join(q.key if hasattr(q, "key") else str(q.idx)
                             for q in p)
            leaves.append(loaded[spath])
        return jax.tree_util.tree_unflatten(flat[1], leaves), step
