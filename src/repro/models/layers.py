"""Primitive layers: norms, dense, RoPE, activations (pure functional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, dtype, stddev):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32).astype(dtype)


def dense_init(key, in_dim, out_shape, *, bias=False, dtype=jnp.float32,
               stddev=None):
    """Kernel (in_dim, *out_shape) with fan-in init."""
    out_shape = (out_shape,) if isinstance(out_shape, int) else tuple(out_shape)
    stddev = stddev if stddev is not None else in_dim ** -0.5
    p = {"kernel": truncated_normal(key, (in_dim,) + out_shape, dtype, stddev)}
    if bias:
        p["bias"] = jnp.zeros(out_shape, dtype)
    return p


def dense(p, x, *, out_ndim=1):
    """x (..., in) @ kernel (in, *out) -> (..., *out)."""
    y = jax.lax.dot_general(
        x, p["kernel"].astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())))
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


def rmsnorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def groupnorm(scale, bias, x, n_groups, eps=1e-5):
    """x (..., n_groups*gdim) normalized per group (RWKV6 head-wise LN)."""
    shape = x.shape
    xg = x.reshape(shape[:-1] + (n_groups, shape[-1] // n_groups))
    x32 = xg.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    y = ((x32 - mu) * jax.lax.rsqrt(var + eps)).reshape(shape)
    return (y * scale + bias).astype(x.dtype)


def activation(name: str, x, gate=None):
    if name == "silu_glu":
        return jax.nn.silu(gate) * x
    if name == "gelu_glu":
        return jax.nn.gelu(gate) * x
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "sq_relu":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope(x, positions, theta=10000.0):
    """x (B, T, H, D), positions (B, T) or (T,) -> rotated x."""
    B, T, H, D = x.shape
    if positions.ndim == 1:
        positions = positions[None, :]
    half = D // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # (B,T,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)
