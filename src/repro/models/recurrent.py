"""Recurrent blocks: RWKV6 (Finch) time-mix and RG-LRU (RecurrentGemma).

Both decode in O(1) state — these are the two archs that run the
long_500k shape. Sharding: the WKV state (B, H, K, V) and the RG-LRU
channel state (B, rnn) are channel-independent recurrences, so the V /
rnn axes ride "model" with zero recurrence-time collectives; only the
out-projections all-reduce.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import annotate


# ---------------------------------------------------------------------------
# RWKV6 time-mix
# ---------------------------------------------------------------------------
def rwkv6_init(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    K = cfg.rwkv_head_dim
    H = d // K
    r_lora = cfg.rwkv_lora_rank
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    p = {
        "w_r": layers.dense_init(ks[0], d, d, dtype=dt),
        "w_k": layers.dense_init(ks[1], d, d, dtype=dt),
        "w_v": layers.dense_init(ks[2], d, d, dtype=dt),
        "w_g": layers.dense_init(ks[3], d, d, dtype=dt),
        "w_w": layers.dense_init(ks[4], d, d, dtype=dt, stddev=1e-3),
        "w_out": layers.dense_init(ks[5], d, d, dtype=dt),
        "lora_a": layers.truncated_normal(ks[6], (d, r_lora), dt, d ** -0.5),
        "lora_b": layers.truncated_normal(ks[7], (r_lora, d), dt, 1e-3),
        "u": layers.truncated_normal(ks[8], (H, K), dt, 0.5),
        # static token-shift mix coefficients for r,k,v,w,g
        "mix_r": jnp.full((d,), 0.5, dt), "mix_k": jnp.full((d,), 0.5, dt),
        "mix_v": jnp.full((d,), 0.5, dt), "mix_w": jnp.full((d,), 0.5, dt),
        "mix_g": jnp.full((d,), 0.5, dt),
        "w_base": jnp.full((d,), -1.5, dt),   # softplus-ish base log decay
        "ln_scale": jnp.ones((d,), dt), "ln_bias": jnp.zeros((d,), dt),
    }
    return {"rwkv": p}


def _rwkv_mix(p, x, x_prev):
    """Token shift: per-channel lerp between x_{t-1} and x_t."""
    xx = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1) - x
    mixed = {}
    for name in ("r", "k", "v", "w", "g"):
        mixed[name] = x + xx * p[f"mix_{name}"].astype(x.dtype)
    return mixed, x[:, -1]


def _rwkv_rkvwg(p, cfg: ModelConfig, mixed):
    d = cfg.d_model
    K = cfg.rwkv_head_dim
    H = d // K
    B, T = mixed["r"].shape[:2]

    def heads(t):
        return t.reshape(B, T, H, K).transpose(0, 2, 1, 3)   # (B,H,T,K)

    r = heads(layers.dense(p["w_r"], mixed["r"]))
    k = heads(layers.dense(p["w_k"], mixed["k"]))
    v = heads(layers.dense(p["w_v"], mixed["v"]))
    g = layers.dense(p["w_g"], mixed["g"])                   # (B,T,d)
    # data-dependent log decay (LoRA): w = -softplus(base + lora) - eps
    ww = (layers.dense({"kernel": p["lora_a"]}, jnp.tanh(mixed["w"]))
          @ p["lora_b"].astype(mixed["w"].dtype))
    w = -jax.nn.softplus(
        (p["w_base"].astype(jnp.float32) + layers.dense(
            p["w_w"], mixed["w"]).astype(jnp.float32) + ww.astype(jnp.float32))
    ) - 1e-3
    w = heads(w.astype(jnp.float32))
    return r, k, v, w, g


def rwkv6_forward(p, cfg: ModelConfig, x, state=None
                  ) -> Tuple[jax.Array, Dict]:
    """x (B,T,d); state {"shift": (B,d), "wkv": (B,H,K,K)} or None."""
    p = p["rwkv"]
    B, T, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    if state is None:
        state = {"shift": jnp.zeros((B, d), x.dtype),
                 "wkv": jnp.zeros((B, H, K, K), jnp.float32)}
    mixed, new_shift = _rwkv_mix(p, x, state["shift"])
    r, k, v, w, g = _rwkv_rkvwg(p, cfg, mixed)
    u = p["u"].astype(jnp.float32)
    impl = "ref" if cfg.attention_impl in ("ref", "blocked") else cfg.attention_impl
    r = annotate(r, "batch", "rheads", "seq", "rkey")
    v = annotate(v, "batch", "rheads", "seq", "rvalue")
    o, wkv = ops.wkv6(r, k, v, w, u, initial_state=state["wkv"], impl=impl,
                      **({"chunk": cfg.wkv_chunk} if impl != "ref" else {}))
    o = annotate(o, "batch", "rheads", "seq", "rvalue")
    o = o.transpose(0, 2, 1, 3).reshape(B, T, d)             # (B,T,d)
    o = layers.groupnorm(p["ln_scale"].astype(jnp.float32),
                         p["ln_bias"].astype(jnp.float32), o, H)
    o = o.astype(x.dtype) * jax.nn.silu(g)
    y = layers.dense(p["w_out"], o)
    return y.astype(x.dtype), {"shift": new_shift, "wkv": wkv}


def rwkv6_decode(p, cfg: ModelConfig, x, state) -> Tuple[jax.Array, Dict]:
    """Single-token step, reusing the T=1 forward (O(1) state)."""
    return rwkv6_forward(p, cfg, x, state)


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------
def rglru_init(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "w_x": layers.dense_init(ks[0], d, d, dtype=dt),
        "w_gate": layers.dense_init(ks[1], d, d, dtype=dt),
        "w_out": layers.dense_init(ks[2], d, d, dtype=dt),
        "conv_w": layers.truncated_normal(ks[3], (cfg.conv_width, d), dt,
                                          cfg.conv_width ** -0.5),
        "conv_b": jnp.zeros((d,), dt),
        "wi": layers.dense_init(ks[4], d, d, bias=True, dtype=dt),
        "wr": layers.dense_init(ks[5], d, d, bias=True, dtype=dt),
        # Lambda param: a = sigmoid(lam) in ~(0.9, 0.999)
        "lam": jnp.linspace(2.2, 6.9, d).astype(dt),
    }
    return {"rglru": p}


def _causal_conv(p, x, conv_state=None):
    """Per-channel causal conv, width W. x (B,T,d)."""
    W = p["conv_w"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)            # (B,T+W-1,d)
    w = p["conv_w"].astype(x.dtype)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    y = y + p["conv_b"].astype(x.dtype)
    return y, xp[:, -(W - 1):]


def _rglru_scan(a, gx):
    """h_t = a_t * h_{t-1} + gx_t via associative scan over T."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2
    aT, bT = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return bT


def rglru_forward(p, cfg: ModelConfig, x, state=None
                  ) -> Tuple[jax.Array, Dict]:
    """x (B,T,d); state {"conv": (B,W-1,d), "h": (B,d)} or None."""
    p = p["rglru"]
    B, T, d = x.shape
    gate = jax.nn.gelu(layers.dense(p["w_gate"], x))
    xb = layers.dense(p["w_x"], x)
    xb = annotate(xb, "batch", "seq", "rnn")
    conv_state = None if state is None else state["conv"]
    xb, new_conv = _causal_conv(p, xb, conv_state)

    i_t = jax.nn.sigmoid(layers.dense(p["wi"], xb).astype(jnp.float32))
    r_t = jax.nn.sigmoid(layers.dense(p["wr"], xb).astype(jnp.float32))
    # a = sigmoid(lam)^(c * r): log a = -c * r * softplus(-lam)
    log_a = -cfg.lru_c * r_t * jax.nn.softplus(-p["lam"].astype(jnp.float32))
    a_t = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 1e-12)) \
        * (i_t * xb.astype(jnp.float32))
    if state is not None:
        # fold initial h into the first step: h_1 = a_1 h_0 + gx_1
        gx = gx.at[:, 0].add(a_t[:, 0] * state["h"].astype(jnp.float32))
    h = _rglru_scan(a_t, gx)                                  # (B,T,d)
    h = annotate(h.astype(x.dtype), "batch", "seq", "rnn")
    y = layers.dense(p["w_out"], h * gate)
    return y, {"conv": new_conv, "h": h[:, -1]}


def rglru_decode(p, cfg: ModelConfig, x, state) -> Tuple[jax.Array, Dict]:
    return rglru_forward(p, cfg, x, state)
