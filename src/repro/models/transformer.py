"""Model assembly: block zoo -> scanned layer stack -> LM / enc-dec.

Layers are stacked by *pattern period* (cfg.block_pattern cycled), so a
homogeneous arch scans all layers in one ``lax.scan`` (compact HLO, fast
compiles) and hybrids like RecurrentGemma (rglru, rglru, local_attn)
scan over periods; remainder layers run unrolled. ``cfg.remat="layer"``
wraps each period in ``jax.checkpoint``.

Public API:
  init_params(key, cfg)                     -> params pytree
  forward(params, cfg, batch, ...)          -> logits [+ cache] [+ aux]
  init_cache(cfg, batch, max_len, ...)      -> decode cache pytree
  decode_step(params, cfg, tokens, cache)   -> (logits, new cache)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers, moe, recurrent
from repro.models.config import ModelConfig
from repro.sharding import annotate


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // 128) * 128


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig, kind: str, *, cross: bool = False):
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p: Dict = {"norm1": layers.rmsnorm_init(cfg.d_model, dt),
               "norm2": layers.rmsnorm_init(cfg.d_model, dt)}
    if kind in ("attn", "local_attn"):
        p["attn"] = attn.attn_init(ks[0], cfg)
    elif kind == "rwkv6":
        p.update(recurrent.rwkv6_init(ks[0], cfg))
    elif kind == "rglru":
        p.update(recurrent.rglru_init(ks[0], cfg))
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = layers.rmsnorm_init(cfg.d_model, dt)
        p["xattn"] = attn.attn_init(ks[2], cfg, cross=True)
    if cfg.is_moe:
        p["moe"] = moe.moe_init(ks[1], cfg)
    else:
        d, f = cfg.d_model, cfg.d_ff
        mlp = {"wi": layers.dense_init(ks[1], d, f, dtype=dt),
               "wo": layers.dense_init(ks[3], f, d, dtype=dt)}
        if cfg.activation.endswith("_glu"):
            mlp["wg"] = layers.dense_init(
                jax.random.fold_in(ks[1], 1), d, f, dtype=dt)
        p["mlp"] = mlp
    return p


def _mlp_forward(p, cfg: ModelConfig, x):
    h = layers.dense(p["wi"], x)
    h = annotate(h, "batch", "seq", "mlp")
    if cfg.activation.endswith("_glu"):
        h = layers.activation(cfg.activation, h, layers.dense(p["wg"], x))
    else:
        h = layers.activation(cfg.activation, h)
    return layers.dense(p["wo"], h)


def _block_forward(p, cfg: ModelConfig, kind: str, x, *, positions,
                   causal=True, enc_out=None, kv_repeat=1, state=None):
    """Returns (x, aux, new_state). state=None => stateless (training)."""
    aux = jnp.float32(0.0)
    h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
    # Megatron-SP transition: boundary residuals are sequence-sharded;
    # gather seq here (one all-gather) so head/expert sharding inside the
    # block never straddles a seq-sharded tensor.
    h = annotate(h, "batch", "seq", "embed")
    new_state = None
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else None
        a = attn.attention_forward(p["attn"], cfg, h, positions=positions,
                                   causal=causal, window=window,
                                   kv_repeat=kv_repeat)
    elif kind == "rwkv6":
        a, new_state = recurrent.rwkv6_forward(p, cfg, h, state)
    elif kind == "rglru":
        a, new_state = recurrent.rglru_forward(p, cfg, h, state)
    # annotate the block *output* seq-sharded before the residual add:
    # XLA then lowers the TP partial-sum as reduce-scatter instead of
    # all-reduce (Megatron-SP), cutting TP collective bytes ~2x/16-way
    a = annotate(a, "batch", "seq_boundary", "embed")
    x = x + a
    if "xattn" in p:
        hx = layers.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        x = x + attn.attention_forward(
            p["xattn"], cfg, hx, positions=None, kv_x=enc_out,
            causal=False, rope_on=False)
    h2 = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
    h2 = annotate(h2, "batch", "seq", "embed")
    if cfg.is_moe:
        m, aux = moe.moe_forward(p["moe"], cfg, h2)
    else:
        m = _mlp_forward(p["mlp"], cfg, h2)
    m = annotate(m, "batch", "seq_boundary", "embed")
    x = x + m
    x = annotate(x, "batch", "seq_boundary", "embed")
    return x, aux, new_state


# ---------------------------------------------------------------------------
# stacked layer groups
# ---------------------------------------------------------------------------
def _pattern_layout(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """(period_kinds, n_periods, remainder_kinds)."""
    pat = tuple(cfg.block_pattern)
    n = cfg.n_layers
    per = len(pat)
    return pat, n // per, tuple(pat[i] for i in range(n % per))


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ModelConfig) -> Dict:
    dt = jnp.dtype(cfg.param_dtype)
    V = padded_vocab(cfg)
    keys = jax.random.split(key, cfg.n_layers + cfg.encoder_layers + 4)
    pat, n_per, rem = _pattern_layout(cfg)
    cross = cfg.is_enc_dec

    params: Dict = {
        "embed": {"table": layers.truncated_normal(
            keys[-1], (V, cfg.d_model), dt, 1.0)},
        "final_norm": layers.rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            keys[-2], cfg.d_model, V, dtype=dt)

    # decoder (or decoder-only) layers, grouped by pattern period
    li = 0
    groups = []
    for g in range(n_per):
        period = {}
        for j, kind in enumerate(pat):
            period[f"p{j}_{kind}"] = _block_init(
                keys[li], cfg, kind, cross=cross)
            li += 1
        groups.append(period)
    if groups:
        params["layers"] = _stack(groups)
    rem_params = []
    for kind in rem:
        rem_params.append((kind, _block_init(keys[li], cfg, kind,
                                             cross=cross)))
        li += 1
    if rem_params:
        params["layers_rem"] = {f"r{i}_{k}": p
                                for i, (k, p) in enumerate(rem_params)}

    if cfg.is_enc_dec:
        enc = []
        for _ in range(cfg.encoder_layers):
            enc.append({"p0_attn": _block_init(keys[li], cfg, "attn")})
            li += 1
        params["encoder"] = _stack(enc)
        params["enc_norm"] = layers.rmsnorm_init(cfg.d_model, dt)
    return params


def _apply_period(p_period, cfg: ModelConfig, x, *, positions, causal,
                  enc_out, kv_repeat):
    aux = jnp.float32(0.0)
    for name in sorted(p_period):
        kind = name.split("_", 1)[1]
        x, a, _ = _block_forward(p_period[name], cfg, kind, x,
                                 positions=positions, causal=causal,
                                 enc_out=enc_out, kv_repeat=kv_repeat)
        aux = aux + a
    return x, aux


def _run_stack(params, cfg: ModelConfig, x, *, positions, causal=True,
               enc_out=None, kv_repeat=1, stack_key="layers",
               rem_key="layers_rem"):
    base_fn = functools.partial(_apply_period, cfg=cfg,
                                positions=positions, causal=causal,
                                enc_out=enc_out, kv_repeat=kv_repeat)

    def period_fn(p, h):
        return base_fn(p, x=h)
    if cfg.remat == "layer":
        period_fn = jax.checkpoint(period_fn, policy=None)

    aux_total = jnp.float32(0.0)
    if stack_key in params:
        def body(h_aux, p_period):
            h, aux = h_aux
            h, a = period_fn(p_period, h)
            return (h, aux + a), None
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params[stack_key])
    if rem_key in params:
        for name in sorted(params[rem_key]):
            kind = name.split("_", 1)[1]
            x, a, _ = _block_forward(params[rem_key][name], cfg, kind, x,
                                     positions=positions, causal=causal,
                                     enc_out=enc_out, kv_repeat=kv_repeat)
            aux_total = aux_total + a
    return x, aux_total


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))
    return annotate(x, "batch", "seq_boundary", "embed")


def lm_logits(params, cfg: ModelConfig, x):
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(x.dtype).T
        logits = x @ w
    else:
        logits = layers.dense(params["lm_head"], x)
    logits = annotate(logits, "batch", "seq", "vocab")
    return logits.astype(jnp.float32)


def encode(params, cfg: ModelConfig, enc_frames):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    x = enc_frames.astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(x.shape[1])
    x, _ = _run_stack(params, cfg, x, positions=pos, causal=False,
                      stack_key="encoder", rem_key="_none")
    return layers.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward_hidden(params, cfg: ModelConfig, tokens, *, enc_frames=None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Backbone only: final hidden states (pre final-norm) + moe aux."""
    B, T = tokens.shape
    enc_out = None
    if cfg.is_enc_dec:
        assert enc_frames is not None
        enc_out = encode(params, cfg, enc_frames)
    x = embed_tokens(params, cfg, tokens)
    positions = jnp.arange(T)
    return _run_stack(params, cfg, x, positions=positions, causal=True,
                      enc_out=enc_out, kv_repeat=cfg.kv_repeat)


def forward(params, cfg: ModelConfig, tokens, *, enc_frames=None
            ) -> Tuple[jax.Array, jax.Array]:
    """Training / prefill forward. Returns (logits, moe aux loss)."""
    x, aux = forward_hidden(params, cfg, tokens, enc_frames=enc_frames)
    return lm_logits(params, cfg, x), aux


# ---------------------------------------------------------------------------
# decode (one token, O(1) per step given the cache)
# ---------------------------------------------------------------------------
def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 start_len) -> Dict:
    if kind in ("attn", "local_attn"):
        size = max_len
        if kind == "local_attn" and cfg.window is not None:
            size = min(max_len, cfg.window)
        c = attn.init_kv_cache(cfg, batch, size, cfg.kv_repeat,
                               dtype=jnp.dtype(cfg.dtype))
        c["len"] = jnp.full((batch,), start_len, jnp.int32)
        return c
    if kind == "rwkv6":
        K = cfg.rwkv_head_dim
        H = cfg.d_model // K
        return {"shift": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
                "wkv": annotate(jnp.zeros((batch, H, K, K), jnp.float32),
                                "batch", "rheads", "rkey", "rvalue")}
    if kind == "rglru":
        return {"conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_model),
                                  jnp.dtype(cfg.dtype)),
                "h": annotate(jnp.zeros((batch, cfg.d_model),
                                        jnp.dtype(cfg.dtype)),
                              "batch", "rnn")}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               start_len: int = 0, params=None, enc_frames=None) -> Dict:
    """Decode cache pytree (optionally with precomputed cross-attn KV)."""
    pat, n_per, rem = _pattern_layout(cfg)
    cache: Dict = {}
    if n_per:
        period = {}
        for j, kind in enumerate(pat):
            one = _block_cache(cfg, kind, batch, max_len, start_len)
            period[f"p{j}_{kind}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_per,) + x.shape), one)
        cache["layers"] = period
    if rem:
        cache["layers_rem"] = {
            f"r{i}_{k}": _block_cache(cfg, k, batch, max_len, start_len)
            for i, k in enumerate(rem)}
    if cfg.is_enc_dec:
        assert params is not None and enc_frames is not None
        enc_out = encode(params, cfg, enc_frames)

        def cross_kv(p_period):
            px = p_period["xattn"]
            k = layers.dense(px["wk"], enc_out)      # (B,Se,KV,hd)
            v = layers.dense(px["wv"], enc_out)
            return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}

        if n_per:
            cache["cross"] = {
                name: jax.vmap(cross_kv)(params["layers"][name])
                for name in params["layers"]}
    return cache


def _block_decode(p, cfg: ModelConfig, kind: str, x, bcache, cross_kv=None):
    h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else None
        a, bcache = attn.attention_decode(p["attn"], cfg, h, bcache,
                                          window=window,
                                          kv_repeat=cfg.kv_repeat)
    elif kind == "rwkv6":
        a, bcache = recurrent.rwkv6_decode(p, cfg, h, bcache)
    elif kind == "rglru":
        a, bcache = recurrent.rglru_decode(p, cfg, h, bcache)
    x = x + a
    if "xattn" in p and cross_kv is not None:
        hx = layers.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        q = layers.dense(p["xattn"]["wq"], hx).transpose(0, 2, 1, 3)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       cross_kv["k"].astype(jnp.float32))
        s = s * cfg.head_dim ** -0.5
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", pr.astype(cross_kv["v"].dtype),
                       cross_kv["v"]).transpose(0, 2, 1, 3)
        y = jnp.einsum("bthd,hdm->btm", o,
                       p["xattn"]["wo"]["kernel"].astype(o.dtype))
        x = x + y
    h2 = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if cfg.is_moe:
        m, _ = moe.moe_dense_forward(p["moe"], cfg, h2)
    else:
        m = _mlp_forward(p["mlp"], cfg, h2)
    return x + m, bcache


def decode_step(params, cfg: ModelConfig, tokens, cache
                ) -> Tuple[jax.Array, Dict]:
    """tokens (B, 1) -> (logits (B, 1, V), updated cache)."""
    x = embed_tokens(params, cfg, tokens)
    pat, n_per, rem = _pattern_layout(cfg)
    new_cache = dict(cache)

    if "layers" in params:
        def body(h, xs):
            p_period, c_period, cross = xs
            new_c = {}
            for name in sorted(p_period):
                kind = name.split("_", 1)[1]
                ckv = cross[name] if cross is not None else None
                h, new_c[name] = _block_decode(p_period[name], cfg, kind,
                                               h, c_period[name], ckv)
            return h, new_c
        cross = cache.get("cross")
        xs = (params["layers"], cache["layers"], cross)
        x, updated = jax.lax.scan(body, x, xs)
        new_cache["layers"] = updated
    if "layers_rem" in params:
        rem_cache = dict(cache["layers_rem"])
        for name in sorted(params["layers_rem"]):
            kind = name.split("_", 1)[1]
            x, rem_cache[name] = _block_decode(
                params["layers_rem"][name], cfg, kind, x, rem_cache[name])
        new_cache["layers_rem"] = rem_cache
    return lm_logits(params, cfg, x), new_cache
