"""Pure-functional JAX model zoo for the ten assigned architectures."""
from repro.models.config import ModelConfig
from repro.models import transformer

__all__ = ["ModelConfig", "transformer"]
