"""Model configuration shared by all ten assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: Optional[int] = None
    head_dim: Optional[int] = None

    # block composition; cycled over layers. Entries:
    #   attn | local_attn | rwkv6 | rglru
    block_pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None   # local attention window

    # dense-MLP variant
    activation: str = "silu_glu"   # silu_glu | gelu | sq_relu
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # encoder-decoder (whisper): n_layers == decoder layers
    encoder_layers: int = 0
    # rwkv
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64
    # rglru
    conv_width: int = 4
    lru_c: float = 8.0

    # numerics / implementation
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_repeat: int = 1                # virtual KV-head expansion (sharding)
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8 (scale-quantized)
    attention_impl: str = "blocked"   # ref | blocked | interpret | pallas
    attn_chunk: int = 512             # q/kv chunk for blocked attention
    wkv_chunk: int = 64
    norm_eps: float = 1e-6
    remat: str = "layer"              # none | layer
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.n_kv_heads is None:
            object.__setattr__(self, "n_kv_heads", self.n_heads)
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def blocks(self) -> Tuple[str, ...]:
        """Per-layer block kinds, pattern cycled to n_layers."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def attention_free(self) -> bool:
        return all(b in ("rwkv6", "rglru") for b in self.blocks)

    @property
    def sub_quadratic(self) -> bool:
        """True if no block needs a full-length dense KV cache."""
        return all(b != "attn" for b in self.blocks)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline cross-checks)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        hd, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
        total = V * d                                   # embed
        if not self.tie_embeddings:
            total += V * d                              # lm head
        for kind in self.blocks:
            if kind in ("attn", "local_attn"):
                total += d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
            elif kind == "rwkv6":
                K = self.rwkv_head_dim
                nh = d // K
                total += 5 * d * d + d                  # r,k,v,g,out + shift
                total += 2 * d * self.rwkv_lora_rank    # w lora
                total += nh * K                         # u
            elif kind == "rglru":
                total += 2 * d * d + d * self.conv_width + 3 * d
            if self.is_moe:
                total += d * self.n_experts             # router
                total += self.n_experts * 3 * d * f     # gated experts
            else:
                n_mats = 3 if self.activation.endswith("_glu") else 2
                total += n_mats * d * f
            total += 2 * d                              # norms
        if self.is_enc_dec:
            # encoder layers + decoder cross-attention
            enc = self.encoder_layers * (
                d * (H * hd) * 2 + 2 * d * (KV * hd)
                + 2 * d * f + 2 * d)
            xattn = self.n_layers * (
                d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d + d)
            total += enc + xattn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k) for 6·N_active·D."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = (self.n_experts - self.moe_top_k) * 3 * d * f
        return self.param_count() - self.n_layers * inactive
