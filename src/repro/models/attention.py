"""GQA attention: init, prefill/train forward, decode step, KV cache.

Implementations:
  - "blocked": pure-JAX online-softmax over (q-chunk, kv-chunk) tiles —
    O(chunk * T) memory, compiles on any backend; q-chunks are remat'd so
    the backward pass recomputes tile logits (flash-style). Default for
    training / long prefill.
  - "ref": plain einsum (small shapes, oracles).
  - "interpret"/"pallas": the Pallas flash kernel (TPU target).

GQA KV heads are *virtually expanded* by ``cfg.kv_repeat`` before use
(and before cache writes) so the head axis matches the mesh "model"
degree — the MaxText-style trade of cache memory for shardability.
Decode with non-head-sharded archs instead shards the cache sequence
axis (flash-decoding style); both are expressed purely through logical
axis annotations.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import annotate

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, *, cross: bool = False) -> Dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d, (H, hd), bias=cfg.qkv_bias, dtype=dt),
        "wk": layers.dense_init(ks[1], d, (KV, hd), bias=cfg.qkv_bias, dtype=dt),
        "wv": layers.dense_init(ks[2], d, (KV, hd), bias=cfg.qkv_bias, dtype=dt),
        "wo": {"kernel": layers.truncated_normal(
            ks[3], (H, hd, d), dt, (H * hd) ** -0.5)},
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = layers.rmsnorm_init(hd, dt)
        p["k_norm"] = layers.rmsnorm_init(hd, dt)
    return p


def _project_qkv(p, cfg: ModelConfig, x, kv_x=None, *, positions=None,
                 rope_on: bool = True, kv_repeat: int = 1):
    kv_x = x if kv_x is None else kv_x
    q = layers.dense(p["wq"], x)                      # (B,T,H,hd)
    k = layers.dense(p["wk"], kv_x)                   # (B,Tk,KV,hd)
    v = layers.dense(p["wv"], kv_x)
    if "q_norm" in p:
        q = layers.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope_on and positions is not None:
        q = layers.rope(q, positions, cfg.rope_theta)
        kpos = positions if k.shape[1] == q.shape[1] else jnp.arange(k.shape[1])
        k = layers.rope(k, kpos, cfg.rope_theta)
    if kv_repeat > 1:
        k = jnp.repeat(k, kv_repeat, axis=2)
        v = jnp.repeat(v, kv_repeat, axis=2)
    q = annotate(q, "batch", "seq", "heads", "head_dim")
    k = annotate(k, "batch", "seq", "kv_heads_act", "head_dim")
    v = annotate(v, "batch", "seq", "kv_heads_act", "head_dim")
    return q, k, v


def blocked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      chunk: int, kv_len=None):
    """Online-softmax tiled attention, (B,H,T,D) layout, any backend."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    scale = D ** -0.5

    def best_chunk(T, c):
        c = min(c, T)
        while T % c:
            c -= 1
        return c

    cq = best_chunk(Tq, chunk)
    ck = best_chunk(Tk, chunk)
    nq, nk = Tq // cq, Tk // ck
    offset = Tk - Tq                     # end-aligned positions

    @functools.partial(jax.checkpoint, policy=None)
    def q_chunk(qi):
        qc = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, 2) * scale
        qpos = qi * cq + jnp.arange(cq)[:, None] + offset

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, 2)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, 2)
            s = jnp.einsum("bhqd,bhkd->bhqk", qc, kc,
                           preferred_element_type=jnp.float32)
            kpos = ki * ck + jnp.arange(ck)[None, :]
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            if kv_len is not None:
                mask = mask[None] & (kpos[None] < kv_len[:, None, None])
                mask = mask[:, None]
            else:
                mask = mask[None, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            pexp = jnp.exp(s - m_new)
            pexp = jnp.where(mask, pexp, 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + pexp.sum(-1, keepdims=True)
            acc = acc * corr + jnp.einsum(
                "bhqk,bhkd->bhqd", pexp.astype(v.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, Hq, cq, 1), NEG_INF, jnp.float32),
                jnp.zeros((B, Hq, cq, 1), jnp.float32),
                jnp.zeros((B, Hq, cq, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        return (acc / jnp.where(l == 0.0, 1.0, l)).astype(q.dtype)

    if nq == 1:
        return q_chunk(0)
    out = jax.lax.map(q_chunk, jnp.arange(nq))       # (nq,B,H,cq,D)
    return jnp.moveaxis(out, 0, 2).reshape(B, Hq, Tq, D)


def attention_forward(p, cfg: ModelConfig, x, *, positions,
                      kv_x=None, causal=True, window=None,
                      rope_on=True, kv_repeat: int = 1):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    q, k, v = _project_qkv(p, cfg, x, kv_x, positions=positions,
                           rope_on=rope_on, kv_repeat=kv_repeat)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    impl = cfg.attention_impl
    if impl == "blocked":
        o = blocked_attention(qt, kt, vt, causal=causal, window=window,
                              chunk=cfg.attn_chunk)
    else:
        o = ops.attention(qt, kt, vt, causal=causal, window=window,
                          impl=impl)
    o = o.transpose(0, 2, 1, 3)
    o = annotate(o, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bthd,hdm->btm", o, p["wo"]["kernel"].astype(o.dtype))
    if "bias" in p["wo"]:
        y = y + p["wo"]["bias"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  kv_repeat: int = 1, dtype=jnp.bfloat16) -> Dict:
    """KV cache; cfg.kv_cache_dtype == "int8" stores scale-quantized
    int8 payloads with per-(pos, head) f32 scales (1/128 overhead) —
    halves decode's dominant HBM-streaming term vs bf16."""
    kvh = cfg.n_kv_heads * kv_repeat
    shape = (batch, kvh, max_len, cfg.head_dim)
    axes = ("batch", "cache_kv_heads", "cache_seq", "head_dim")
    if cfg.kv_cache_dtype == "int8":
        cache = {
            "k": annotate(jnp.zeros(shape, jnp.int8), *axes),
            "v": annotate(jnp.zeros(shape, jnp.int8), *axes),
            "k_scale": annotate(
                jnp.zeros(shape[:-1] + (1,), jnp.float32), *axes),
            "v_scale": annotate(
                jnp.zeros(shape[:-1] + (1,), jnp.float32), *axes),
        }
    else:
        cache = {
            "k": annotate(jnp.zeros(shape, dtype), *axes),
            "v": annotate(jnp.zeros(shape, dtype), *axes),
        }
    cache["len"] = jnp.zeros((batch,), jnp.int32)
    return cache


def _quantize(x, axis=-1):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale


def attention_decode(p, cfg: ModelConfig, x, cache: Dict, *,
                     window=None, kv_repeat: int = 1
                     ) -> Tuple[jax.Array, Dict]:
    """One-token decode: x (B, 1, d), cache from init_kv_cache.

    When the cache is smaller than the logical sequence (local-attention
    ring buffer, size == window), writes wrap modulo the cache length —
    the ring then always holds exactly the attention window, and no
    window mask is needed (rope was applied at write time).
    """
    S = cache["k"].shape[2]
    ring = window is not None and S <= window
    pos = cache["len"][:, None]                       # (B,1) logical pos
    q, k, v = _project_qkv(p, cfg, x, positions=pos, kv_repeat=kv_repeat)
    write_pos = cache["len"] % S if ring else cache["len"]
    sel = (jnp.arange(S)[None, :] == write_pos[:, None])   # (B,S) bool
    sel4 = sel[:, None, :, None]
    knew = k.transpose(0, 2, 1, 3)                    # (B,KV,1,hd)
    vnew = v.transpose(0, 2, 1, 3)
    axes = ("batch", "cache_kv_heads", "cache_seq", "head_dim")
    quantized = "k_scale" in cache
    new_cache: Dict = {}
    if quantized:
        kq, ks = _quantize(knew)
        vq, vs = _quantize(vnew)
        ck = jnp.where(sel4, kq, cache["k"])
        cv = jnp.where(sel4, vq, cache["v"])
        cks = jnp.where(sel[:, None, :, None], ks, cache["k_scale"])
        cvs = jnp.where(sel[:, None, :, None], vs, cache["v_scale"])
        new_cache["k_scale"] = annotate(cks, *axes)
        new_cache["v_scale"] = annotate(cvs, *axes)
        kk_full = ck.astype(jnp.float32) * cks
        vv_full = cv.astype(jnp.float32) * cvs
    else:
        ck = jnp.where(sel4, knew.astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(sel4, vnew.astype(cache["v"].dtype), cache["v"])
        kk_full, vv_full = ck, cv
    ck = annotate(ck, *axes)
    cv = annotate(cv, *axes)
    new_cache["k"] = ck
    new_cache["v"] = cv
    new_len = cache["len"] + 1
    valid = jnp.minimum(new_len, S) if ring else new_len

    qt = q.transpose(0, 2, 1, 3)                      # (B,H,1,hd)
    Hq, Hkv = qt.shape[1], ck.shape[1]
    group = Hq // Hkv
    kk = (jnp.repeat(kk_full, group, axis=1) if group > 1 else kk_full)
    vv = (jnp.repeat(vv_full, group, axis=1) if group > 1 else vv_full)
    s = jnp.einsum("bhqd,bhkd->bhqk", qt.astype(jnp.float32),
                   kk.astype(jnp.float32)) * cfg.head_dim ** -0.5
    kpos = jnp.arange(S)[None, None, None, :]
    mask = kpos < valid[:, None, None, None]
    if window is not None and not ring:
        mask &= kpos > (new_len[:, None, None, None] - 1 - window)
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", pr.astype(vv.dtype), vv)
    o = o.transpose(0, 2, 1, 3).astype(x.dtype)        # (B,1,H,hd)
    y = jnp.einsum("bthd,hdm->btm", o, p["wo"]["kernel"].astype(o.dtype))
    if "bias" in p["wo"]:
        y = y + p["wo"]["bias"].astype(y.dtype)
    new_cache["len"] = new_len
    return y, new_cache
