"""Top-k MoE with grouped, capacity-bounded einsum dispatch (GShard/t5x).

Tokens are split into groups of ``moe_group_size``; each group competes
for per-group capacity C = ceil(S*k/E * capacity_factor). The dispatch
one-hot is (G, S, E, C) — with S ~ 256 the dispatch-einsum FLOPs stay
O(20%) of expert FLOPs and the tensor is a few hundred MB transient,
instead of the quadratic-in-S blowup of ungrouped dispatch.

Sharding: groups ride the token/batch axes ("pod","data"); the expert
axis rides "model" when divisible (granite-1b: 32 experts), otherwise
the per-expert d_ff rides "model" (granite-3b: 40 experts). Overflowed
tokens fall through the residual; a Switch-style aux loss is returned.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import annotate


def moe_init(key, cfg: ModelConfig) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": layers.dense_init(ks[0], d, E, dtype=dt),
        "wi": {"kernel": layers.truncated_normal(ks[1], (E, d, f), dt,
                                                 d ** -0.5)},
        "wg": {"kernel": layers.truncated_normal(ks[2], (E, d, f), dt,
                                                 d ** -0.5)},
        "wo": {"kernel": layers.truncated_normal(ks[3], (E, f, d), dt,
                                                 f ** -0.5)},
    }


GROUP_SIZE = 256


def moe_dense_forward(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """Capacity-free MoE (decode path): every token gets its exact top-k.

    Computes all experts for the token batch (T is 1 at decode, so the
    E/k-fold extra FLOPs are negligible) — avoids the batch-dependent
    capacity-drop semantics of the dispatch path.
    """
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    xt = x.reshape(B * T, d)
    logits = layers.dense(p["router"], xt.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], expert_idx].set(gate_vals)
    wi = p["wi"]["kernel"].astype(xt.dtype)
    wg = p["wg"]["kernel"].astype(xt.dtype)
    wo = p["wo"]["kernel"].astype(xt.dtype)
    h = jnp.einsum("nd,edf->nef", xt, wi)
    g = jnp.einsum("nd,edf->nef", xt, wg)
    h = layers.activation("silu_glu", h, g)
    y = jnp.einsum("nef,efd,ne->nd", h, wo, gates.astype(xt.dtype))
    return y.reshape(B, T, d), jnp.float32(0.0)


def moe_forward(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x (B, T, d) -> (y (B, T, d), aux_loss scalar)."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    n = B * T
    S = min(GROUP_SIZE, n)
    G = n // S
    xt = x.reshape(G, S, d)
    xt = annotate(xt, "batch", None, "embed")

    logits = layers.dense(p["router"], xt.astype(jnp.float32))   # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (G,S,k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # Switch aux loss: E * mean_e(frac routed to e) * mean_e(router prob e)
    onehot_top1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.sum(onehot_top1.mean((0, 1)) * probs.mean((0, 1)))

    capacity = max(int(cfg.capacity_factor * S * k / E), 4)

    sel = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)          # (G,S,k,E)
    flat = sel.reshape(G, S * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                         # (G,S*k,E)
    pos = (pos.reshape(G, S, k, E) * sel).sum(-1)                 # (G,S,k)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1,
                            dtype=xt.dtype)[..., :capacity]       # (G,S,k,C)
    disp = jnp.einsum("gske,gskc->gsec", sel.astype(xt.dtype), pos_oh)
    comb = jnp.einsum("gske,gskc,gsk->gsec", sel.astype(jnp.float32),
                      pos_oh.astype(jnp.float32),
                      gate_vals.astype(jnp.float32)).astype(xt.dtype)

    ex_in = jnp.einsum("gsec,gsd->gecd", disp, xt)                # (G,E,C,d)
    ex_in = annotate(ex_in, "batch", "experts", "capacity", "embed")
    wi = p["wi"]["kernel"].astype(xt.dtype)
    wg = p["wg"]["kernel"].astype(xt.dtype)
    wo = p["wo"]["kernel"].astype(xt.dtype)
    h = jnp.einsum("gecd,edf->gecf", ex_in, wi)
    g = jnp.einsum("gecd,edf->gecf", ex_in, wg)
    h = layers.activation("silu_glu", h, g)
    h = annotate(h, "batch", "experts", "capacity", "mlp")
    ex_out = jnp.einsum("gecf,efd->gecd", h, wo)
    ex_out = annotate(ex_out, "batch", "experts", "capacity", "embed")

    y = jnp.einsum("gsec,gecd->gsd", comb, ex_out)
    return y.reshape(B, T, d), aux
