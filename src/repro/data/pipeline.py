"""Deterministic synthetic token pipeline with stateless resume.

Every batch is a pure function of (seed, step, shard) — a restarted or
re-sharded job regenerates exactly the token stream it would have seen,
with no iterator state to checkpoint (the "stateless data skipping"
pattern used at scale). Shards slice the global batch, so elastic
re-sharding (different host count after a failure) stays bit-identical
as long as global_batch is unchanged.

The synthetic text is a Zipf-ish Markov stream: enough structure for a
~100M-param model to show steadily decreasing loss in the e2e example.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step]))


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Global batch for `step`, sliced to this shard."""
    rng = _batch_rng(cfg, step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # zipf-ish unigram pool mixed with short-range repetition structure
    base = (rng.zipf(1.3, size=(B, S + 1)) - 1) % V
    rep = np.roll(base, 7, axis=1)
    mask = rng.random((B, S + 1)) < 0.35
    toks = np.where(mask, rep, base).astype(np.int32)
    tokens, labels = toks[:, :-1], toks[:, 1:]
    shard_sz = B // cfg.n_shards
    lo = cfg.shard * shard_sz
    hi = lo + shard_sz
    return {"tokens": tokens[lo:hi], "labels": labels[lo:hi]}


def iterate(cfg: DataConfig, start_step: int = 0
            ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, step)
        step += 1
