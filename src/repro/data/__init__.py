from repro.data.pipeline import DataConfig, iterate, make_batch
