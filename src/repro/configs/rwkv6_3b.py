"""rwkv6-3b [ssm] — RWKV-6 "Finch": 32L, d=2560, attn-free,
data-dependent decay [arXiv:2404.05892; hf]. 40 WKV heads of 64.
Channel-mix approximated by a squared-ReLU FFN (DESIGN.md §Arch notes).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560,
    n_heads=40, n_kv_heads=40, head_dim=64, d_ff=8960, vocab_size=65536,
    block_pattern=("rwkv6",), activation="sq_relu", rwkv_head_dim=64)

def smoke():
    return ModelConfig(
        name="rwkv6-smoke", family="ssm", n_layers=2, d_model=128,
        n_heads=2, head_dim=64, d_ff=256, vocab_size=512,
        block_pattern=("rwkv6",), activation="sq_relu", rwkv_head_dim=64,
        dtype="float32", remat="none")
