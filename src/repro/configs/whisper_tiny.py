"""whisper-tiny [audio] — enc-dec 4L+4L d=384 6H d_ff=1536 vocab=51865
[arXiv:2212.04356; unverified]. Conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, seq//2, d); decoder length is
seq//2 so the cell's token budget matches seq_len. RoPE replaces the
original learned/sinusoidal positions (DESIGN.md adaptation note)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, head_dim=64, d_ff=1536, vocab_size=51865,
    encoder_layers=4, activation="gelu")

def smoke():
    return ModelConfig(
        name="whisper-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        encoder_layers=2, activation="gelu", dtype="float32", remat="none",
        attn_chunk=16)
