"""recurrentgemma-9b [hybrid] — 38L d=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention 1:2 pattern, window 2048
[arXiv:2402.19427; unverified]. 38 = 12 x (rglru,rglru,local_attn) + 2."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"), window=2048,
    activation="gelu_glu")

def smoke():
    return ModelConfig(
        name="rg-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
        block_pattern=("rglru", "rglru", "local_attn"), window=16,
        activation="gelu_glu", dtype="float32", remat="none", attn_chunk=16)
