"""qwen1.5-4b [dense] — 40L d=2560 20H (kv=20, MHA) d_ff=6912
vocab=151936; QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].
n_heads=20 is not divisible by the 16-way model axis -> attention runs
replicated with flash-decoding-style cache-sequence sharding (rules.py).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
    n_heads=20, n_kv_heads=20, head_dim=128, d_ff=6912, vocab_size=151936,
    qkv_bias=True, activation="silu_glu")

def smoke():
    return ModelConfig(
        name="qwen1.5-smoke", family="dense", n_layers=2, d_model=80,
        n_heads=5, n_kv_heads=5, head_dim=16, d_ff=160, vocab_size=512,
        qkv_bias=True, dtype="float32", remat="none", attn_chunk=32)
