"""The paper's own simulated GPU configuration (Table II) as a config
module, so benchmarks and tests share one source of truth."""
from repro.core.geometry import PAPER_GEOMETRY

CONFIG = PAPER_GEOMETRY
