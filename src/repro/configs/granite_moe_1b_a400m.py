"""granite-moe-1b-a400m [moe] — 24L d=1024 16H (GQA kv=8) per-expert
d_ff=512, vocab=49155, 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]. 32 % 16 == 0 -> true
expert parallelism over the model axis."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=8, head_dim=64, d_ff=512, vocab_size=49155,
    n_experts=32, moe_top_k=8, activation="silu_glu")

def smoke():
    return ModelConfig(
        name="granite1b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=32, vocab_size=512,
        n_experts=4, moe_top_k=2, dtype="float32", remat="none",
        attn_chunk=32)
