"""Per-architecture configs (--arch <id>). Each module exports CONFIG
(the exact assigned configuration) and smoke() (a reduced same-family
config for CPU tests)."""
import importlib
from typing import Dict

_MODULES = {
    "rwkv6-3b": "rwkv6_3b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen1.5-4b": "qwen1_5_4b",
    "nemotron-4-15b": "nemotron_4_15b",
    "stablelm-12b": "stablelm_12b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-tiny": "whisper_tiny",
    "chameleon-34b": "chameleon_34b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; one of {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").CONFIG


def get_smoke_config(arch: str):
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").smoke()


def all_configs() -> Dict[str, object]:
    return {a: get_config(a) for a in ARCH_IDS}
