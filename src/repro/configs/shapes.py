"""Assigned input-shape set (same four cells for every LM arch).

train_* lowers train_step; prefill_* lowers a full-sequence forward;
decode_*/long_* lower serve_step (one new token against a KV cache of
seq_len). long_500k requires sub-quadratic attention and only runs for
SSM/hybrid archs (see DESIGN.md shape-skip table).
"""
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 0.5M-token dense KV cache is the "
                "quadratic cost this shape excludes (DESIGN.md)")
    return None
