"""chameleon-34b [vlm] — 48L d=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early fusion: VQ image tokens share the text vocabulary
[arXiv:2405.09818; unverified]. The VQ tokenizer is a STUB —
input_specs() provides mixed text/image token ids; qk_norm per the
paper's training-stability recipe."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22016, vocab_size=65536,
    qk_norm=True, activation="silu_glu")

def smoke():
    return ModelConfig(
        name="chameleon-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        qk_norm=True, dtype="float32", remat="none", attn_chunk=32)
