"""granite-moe-3b-a800m [moe] — 32L d=1536 24H (GQA kv=8) per-expert
d_ff=512, vocab=49155, 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
40 experts do not divide the 16-way model axis -> per-expert d_ff is
model-sharded instead (rules.py)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, head_dim=64, d_ff=512, vocab_size=49155,
    n_experts=40, moe_top_k=8, activation="silu_glu")

def smoke():
    return ModelConfig(
        name="granite3b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=32, vocab_size=512,
        n_experts=5, moe_top_k=2, dtype="float32", remat="none",
        attn_chunk=32)
