"""qwen3-0.6b [dense] — 28L d=1024 16H (GQA kv=8) d_ff=3072
vocab=151936; qk_norm [hf:Qwen/Qwen3-8B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense", n_layers=28, d_model=1024,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=3072, vocab_size=151936,
    qk_norm=True, activation="silu_glu", rope_theta=1e6)

def smoke():
    return ModelConfig(
        name="qwen3-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        qk_norm=True, dtype="float32", remat="none", attn_chunk=32)
