"""Training step: loss -> grad -> clip -> AdamW, with optional
microbatch gradient accumulation (lax.scan) for memory headroom."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward_hidden
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state
from repro.train.loss import chunked_cross_entropy

AUX_WEIGHT = 0.01


def loss_fn(params, cfg: ModelConfig, batch) -> jax.Array:
    h, aux = forward_hidden(params, cfg, batch["tokens"],
                            enc_frames=batch.get("enc_frames"))
    loss = chunked_cross_entropy(params, cfg, h, batch["labels"])
    return loss + AUX_WEIGHT * aux


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, cfg, batch)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]
                   ) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
        params = state["params"]
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                l, g = grads_of(params, mb)
                return (carry[0] + l,
                        jax.tree.map(jnp.add, carry[1], g)), None
            split = jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            zero = (jnp.float32(0.0),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(micro, zero, split)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        new_params, opt, metrics = apply_updates(
            opt_cfg, params, grads, state["opt"])
        metrics["loss"] = loss
        return {"params": new_params, "opt": opt}, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig) -> Dict[str, Any]:
    from repro.models.transformer import init_params
    params = init_params(key, cfg)
    return {"params": params, "opt": init_opt_state(params)}
