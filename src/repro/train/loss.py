"""Sequence-chunked softmax cross-entropy.

The (B, S, V) logits tensor of a 256k-vocab model at 1M tokens is ~1 TB
in f32 — never materialized: the final hidden states are scanned in
sequence chunks, each chunk projects + losses + (in backward, recomputes
under jax.checkpoint). This is the memory-critical path for nemotron /
recurrentgemma (256k vocab) training cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import annotate


def _chunk_xent(params, cfg: ModelConfig, h_chunk, labels_chunk):
    from repro.models.transformer import lm_logits
    logits = lm_logits(params, cfg, h_chunk)          # (B, c, V) f32
    V = logits.shape[-1]
    mask = labels_chunk >= 0
    labels_safe = jnp.where(mask, labels_chunk, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None],
                               axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum(), mask.sum()


def chunked_cross_entropy(params, cfg: ModelConfig, h, labels,
                          chunk: int = 128):
    """h (B,S,d) final hidden states; labels (B,S) with -1 = pad."""
    B, S, d = h.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c
    f = functools.partial(_chunk_xent, params, cfg)
    f = jax.checkpoint(f, policy=None)

    def body(carry, i):
        tot, cnt = carry
        hc = jax.lax.dynamic_slice_in_dim(h, i * c, c, 1)
        lc = jax.lax.dynamic_slice_in_dim(labels, i * c, c, 1)
        s, m = f(hc, lc)
        return (tot + s, cnt + m), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)),
                                 jnp.arange(n))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32)
