from repro.train.step import (init_train_state, loss_fn, make_train_step)
from repro.train.loss import chunked_cross_entropy
