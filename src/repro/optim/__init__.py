from repro.optim.adamw import (AdamWConfig, apply_updates, init_opt_state,
                               schedule, zero1_shardings, global_norm)
from repro.optim import compression
