"""AdamW with cosine schedule, global-norm clipping, ZeRO-1 sharding.

Pure-pytree implementation (no optax dependency). ``zero1_shardings``
derives optimizer-state shardings that additionally shard the first
unsharded, divisible dimension of every state leaf over the data axes —
optimizer memory scales 1/DP like ZeRO stage 1; XLA inserts the
all-gather at update time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(cfg: AdamWConfig, params, grads, opt_state
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-6))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        d = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree.unflatten(tdef, [n[0] for n in new])
    mm = jax.tree.unflatten(tdef, [n[1] for n in new])
    vv = jax.tree.unflatten(tdef, [n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params, {"m": mm, "v": vv, "step": step}, metrics


def zero1_shardings(param_shardings, mesh: Mesh,
                    params_shape) -> Dict[str, Any]:
    """m/v shardings = param sharding + data axes on the first free dim."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]

    def extend(sh: NamedSharding, shape: jax.ShapeDtypeStruct):
        spec = list(sh.spec) + [None] * (len(shape.shape) - len(sh.spec))
        for i, (dim, cur) in enumerate(zip(shape.shape, spec)):
            if cur is None and dsize > 1 and dim % dsize == 0:
                spec[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                break
        return NamedSharding(mesh, P(*spec))

    mv = jax.tree.map(extend, param_shardings, params_shape)
    return {"m": mv, "v": mv,
            "step": NamedSharding(mesh, P())}
