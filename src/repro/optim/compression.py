"""Int8 error-feedback gradient compression for cross-replica reduction.

The distributed-optimization trick: before the data-parallel gradient
all-reduce, each replica quantizes its gradient to int8 with a per-
tensor scale and keeps the quantization residual in a local error-
feedback buffer that is added back the next step (Seide et al. 1-bit
SGD / EF-SGD semantics, int8 variant). Wire bytes drop 4x vs f32 with
no asymptotic convergence penalty.

Two entry points:
  * compress/decompress + ef buffers — pure functions for tests and for
    the wire format used by the checkpoint/elastic layer;
  * all_reduce_compressed — a shard_map psum over the quantized int
    payload (the actual collective carries int32 accumulations of int8
    values; scales ride a tiny side-channel psum).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array,
                                                    jax.Array]:
    """(grad, error_buffer) -> (q int8, scale f32 scalar, new_error)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_buffers(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress_tree(grads: Any, errors: Any) -> Tuple[Any, Any, Any]:
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return (jax.tree.unflatten(tdef, qs),
            jax.tree.unflatten(tdef, scales),
            jax.tree.unflatten(tdef, errs))


def all_reduce_compressed(grads: Any, errors: Any, axis_name: str
                          ) -> Tuple[Any, Any]:
    """Inside shard_map: mean-reduce int8-compressed grads over axis.

    Returns (reduced f32 grads, new error buffers). The psum payload is
    int8 widened to int32 (sum of <=2^24 replicas' int8 fits exactly);
    per-tensor scales are psum'd alongside (replicas may have different
    scales, so each replica's contribution is de-scaled after the sum
    of q*scale — implemented as psum of the already-scaled f16 payload
    would lose the compression, so we psum q and the max-scale and
    accept the standard EF approximation of a shared scale).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, s, ne = compress(g, e)
        s_shared = jax.lax.pmax(s, axis_name)
        # re-quantize against the shared scale so the integer sum is exact
        g32 = g.astype(jnp.float32) + e
        q = jnp.clip(jnp.round(g32 / s_shared), -127, 127).astype(jnp.int8)
        ne = g32 - q.astype(jnp.float32) * s_shared
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * s_shared / n), ne

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
