from repro.sharding.rules import (annotate, make_rules, param_axes,
                                  param_shardings, rules_context,
                                  logical_to_spec)

__all__ = ["annotate", "make_rules", "param_axes", "param_shardings",
           "rules_context", "logical_to_spec"]
