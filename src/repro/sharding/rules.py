"""Logical-axis sharding: one rules table per (arch, mesh).

Model code annotates activations with *logical* axis names via
``annotate(x, "batch", "seq", "embed")`` and parameters carry logical
axes by path pattern (``param_axes``). ``make_rules`` maps logical axes
to mesh axes per architecture:

  head-TP archs (n_heads % model == 0): attention heads over "model",
      KV heads virtually expanded to the model degree (MaxText-style);
  replicated-attention archs (qwen1.5 H=20, whisper H=6, granite-3b
      H=24): attention params replicated, decode KV cache sharded over
      the *cache sequence* axis (flash-decoding style);
  rwkv6: the WKV state and v/gate/out projections shard the V channel
      ("rvalue") over "model" — the recurrence is independent per V
      column, so only the out-projection all-reduces;
  rglru: diagonal recurrence is channel-independent -> "rnn" over model;
  MoE: expert axis over "model" when divisible (granite-1b, 32e), else
      per-expert d_ff over "model" (granite-3b, 40e);
  residual stream: batch over ("pod","data"), boundary activations
      sequence-sharded over "model" (Megatron sequence parallelism).

Batch-1 shapes (long_500k) drop the batch mapping instead of failing.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Optional[Tuple[str, ...]]]

_state = threading.local()


def _current() -> Optional[Tuple[Mesh, Rules]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def rules_context(mesh: Optional[Mesh], rules: Optional[Rules]):
    """Activate (mesh, rules) for annotate() within the context."""
    prev = _current()
    _state.ctx = (mesh, rules) if mesh is not None else None
    try:
        yield
    finally:
        _state.ctx = prev


def logical_to_spec(axes: Tuple[Optional[str], ...], rules: Rules) -> P:
    parts = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            parts.append(None)
        elif isinstance(m, tuple):
            parts.append(m if len(m) > 1 else m[0])
        else:
            parts.append(m)
    return P(*parts)


def annotate(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without context)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter logical axes by path pattern
# ---------------------------------------------------------------------------
#: pattern -> logical axes (matched against 'a/b/c' flattened path).
_PARAM_PATTERNS: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"(?:^|.*/)embed/table$", ("vocab", "embed")),
    (r"(?:^|.*/)lm_head/kernel$", ("embed", "vocab")),
    (r"(?:^|.*/).*(attn|xattn)/w[qQ]/kernel$", ("embed", "heads", "head_dim")),
    (r"(?:^|.*/).*(attn|xattn)/w[kv]/kernel$", ("embed", "kv_heads", "head_dim")),
    (r"(?:^|.*/).*(attn|xattn)/wo/kernel$", ("heads", "head_dim", "embed")),
    (r"(?:^|.*/).*(attn|xattn)/w[qQ]/bias$", ("heads", "head_dim")),
    (r"(?:^|.*/).*(attn|xattn)/w[kv]/bias$", ("kv_heads", "head_dim")),
    (r"(?:^|.*/).*(attn|xattn)/wo/bias$", ("embed",)),
    (r"(?:^|.*/)(q|k)_norm/scale$", ("head_dim",)),
    (r"(?:^|.*/)mlp/wi/kernel$", ("embed", "mlp")),
    (r"(?:^|.*/)mlp/wg/kernel$", ("embed", "mlp")),
    (r"(?:^|.*/)mlp/wo/kernel$", ("mlp", "embed")),
    (r"(?:^|.*/)moe/router/kernel$", ("embed", "experts")),
    (r"(?:^|.*/)moe/wi/kernel$", ("experts", "embed", "mlp")),
    (r"(?:^|.*/)moe/wg/kernel$", ("experts", "embed", "mlp")),
    (r"(?:^|.*/)moe/wo/kernel$", ("experts", "mlp", "embed")),
    (r"(?:^|.*/)rwkv/w_(r|k|w)/kernel$", ("embed", "embed2")),
    (r"(?:^|.*/)rwkv/w_(v|g)/kernel$", ("embed", "rvalue_flat")),
    (r"(?:^|.*/)rwkv/w_out/kernel$", ("rvalue_flat", "embed")),
    (r"(?:^|.*/)rwkv/mix_.*$", ("embed",)),
    (r"(?:^|.*/)rwkv/lora_(a)$", ("embed", "lora")),
    (r"(?:^|.*/)rwkv/lora_(b)$", ("lora", "embed")),
    (r"(?:^|.*/)rwkv/u$", ("rheads", "rkey")),
    (r"(?:^|.*/)rwkv/w_base$", ("embed",)),
    (r"(?:^|.*/)rwkv/ln_(scale|bias)$", ("rvalue_flat",)),
    (r"(?:^|.*/)rglru/w_(x|gate)/kernel$", ("embed", "rnn")),
    (r"(?:^|.*/)rglru/w_out/kernel$", ("rnn", "embed")),
    (r"(?:^|.*/)rglru/conv_w$", ("conv", "rnn")),
    (r"(?:^|.*/)rglru/conv_b$", ("rnn",)),
    (r"(?:^|.*/)rglru/(wi|wr)/kernel$", ("embed", "rnn")),
    (r"(?:^|.*/)rglru/(wi|wr)/bias$", ("rnn",)),
    (r"(?:^|.*/)rglru/lam$", ("rnn",)),
    (r".*norm.*/(scale|bias)$", ("embed",)),
    (r"(?:^|.*/)bias$", ("mlp",)),           # mlp wi bias (rare)
)


def param_axes(params) -> object:
    """Mirror pytree of logical-axes tuples, resolved by path pattern."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat[0]:
        spath = "/".join(
            p.key if hasattr(p, "key") else str(p.idx) for p in path)
        for pat, axes in _PARAM_PATTERNS:
            if re.match(pat, spath):
                if len(axes) != leaf.ndim:
                    # stacked-layer leading axis
                    if len(axes) + 1 == leaf.ndim:
                        axes = (None,) + axes
                    else:
                        raise ValueError(
                            f"{spath}: rank {leaf.ndim} vs axes {axes}")
                out.append(axes)
                break
        else:
            raise ValueError(f"no axis rule for param {spath}")
    return jax.tree_util.tree_unflatten(flat[1], out)


def param_shardings(params, mesh: Mesh, rules: Rules):
    axes = param_axes(params)
    return jax.tree.map(
        lambda a: NamedSharding(mesh, logical_to_spec(a, rules)),
        axes, is_leaf=lambda a: isinstance(a, tuple))


# ---------------------------------------------------------------------------
# per-arch rule construction
# ---------------------------------------------------------------------------
def make_rules(cfg, mesh: Mesh, *, batch_size: Optional[int] = None,
               seq_shard_boundary: bool = True,
               profile: str = "tp") -> Rules:
    """Logical->mesh mapping for a ModelConfig on a mesh.

    profile:
      "tp" — baseline: model axis carries vocab/mlp/heads tensor
             parallelism (+ sequence-parallel boundaries);
      "dp" — pure data parallelism: the model axis joins the batch axes
             and parameters replicate (ZeRO-1 still shards optimizer
             state). Roofline-optimal for small models where TP
             collectives dominate compute (EXPERIMENTS.md §Perf).
    """
    names = mesh.axis_names
    model_ax = "model" if "model" in names else None
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    msize = mesh.shape["model"] if model_ax else 1
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]

    if profile == "dp" and model_ax:
        batch_axes: Optional[Tuple[str, ...]] = data_axes + (model_ax,)
        total = dsize * msize
        if batch_size is not None and batch_size % total:
            batch_axes = (data_axes if batch_size % max(dsize, 1) == 0
                          else None)
        none_rules: Rules = {k: None for k in (
            "seq", "seq_boundary", "embed", "embed2", "vocab", "mlp",
            "heads", "kv_heads", "kv_heads_act", "head_dim",
            "cache_kv_heads", "cache_seq", "experts", "expert_mlp",
            "capacity", "rheads", "rkey", "rvalue", "rvalue_flat",
            "lora", "rnn", "conv", "frames")}
        none_rules["batch"] = batch_axes
        return none_rules

    head_tp = (cfg.n_heads % msize == 0) if msize > 1 else False
    moe_ep = cfg.is_moe and cfg.n_experts % msize == 0

    batch = data_axes if data_axes else None
    if batch_size is not None and batch_size % max(dsize, 1):
        batch = None    # batch-1 decode shapes: leave data idle

    rules: Rules = {
        "batch": batch,
        "seq": None,
        # Megatron-style sequence parallelism on residual boundaries
        "seq_boundary": (model_ax,) if seq_shard_boundary else None,
        "embed": None,
        "embed2": None,
        "vocab": (model_ax,),
        "mlp": (model_ax,),
        "heads": (model_ax,) if head_tp else None,
        # params keep the raw KV head count (may not divide the mesh);
        # activations are annotated post-expansion with kv_heads_act
        "kv_heads": ((model_ax,) if head_tp
                     and cfg.n_kv_heads % msize == 0 else None),
        "kv_heads_act": (model_ax,) if head_tp else None,
        "head_dim": None,
        # decode KV cache: heads when head-TP, else cache-sequence
        "cache_kv_heads": (model_ax,) if head_tp else None,
        "cache_seq": None if head_tp else (model_ax,),
        "experts": (model_ax,) if moe_ep else None,
        "expert_mlp": None if moe_ep else (model_ax,),
        "capacity": None,
        # rwkv: shard the V channel of the state everywhere it appears
        "rheads": None,
        "rkey": None,
        "rvalue": (model_ax,),
        "rvalue_flat": (model_ax,),
        "lora": None,
        "rnn": (model_ax,),
        "conv": None,
        # frames for the audio encoder stub
        "frames": None,
    }
    if moe_ep:
        # experts carry the model axis; per-expert d_ff stays local
        rules["mlp"] = None
    return rules
