"""Version compatibility shims for the moving ``jax.sharding`` surface.

The repo targets both older jax (0.4.3x: no ``jax.sharding.AxisType``,
no ``jax.set_mesh``, ``shard_map`` still under ``jax.experimental``) and
newer releases where those are the blessed spellings. Everything that
touches mesh construction or global-mesh activation goes through here so
tests and launch scripts run unchanged on either.
"""
from __future__ import annotations

import contextlib
from typing import Sequence

import jax
import numpy as np

try:  # jax >= 0.5-ish
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

#: Whether this jax has explicit axis types on meshes.
HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def shard_map_norep(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with the replication check disabled.

    Required when the mapped body contains ops without a replication
    rule — ``pallas_call`` is the one in this repo (the fused-probe
    simulator backends). The flag's spelling has moved across jax
    releases (``check_rep`` -> ``check_vma``), so resolve it here.
    """
    import inspect
    params = inspect.signature(shard_map).parameters
    for kw in ("check_rep", "check_vma"):
        if kw in params:
            return shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **{kw: False})
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if HAS_AXIS_TYPES:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=axis_types)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def make_mesh_1d(n_devices: int, axis_name: str):
    """A 1-D mesh over the first ``n_devices`` local devices.

    Unlike :func:`make_mesh` / ``jax.make_mesh`` this slices the device
    list explicitly, so sweeps can shard over a subset of the host's
    devices (``jax.make_mesh`` insists on consuming a specific count in
    some versions and reorders devices in others).
    """
    devs = np.asarray(jax.devices()[:n_devices])
    if HAS_AXIS_TYPES:
        return jax.sharding.Mesh(
            devs, (axis_name,),
            axis_types=(jax.sharding.AxisType.Auto,))
    return jax.sharding.Mesh(devs, (axis_name,))


def activate_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` (new) -> ``jax.sharding.use_mesh`` (mid) -> no-op
    (old jax, where explicit NamedShardings on every jit boundary carry
    the mesh and no ambient mesh exists).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext()
