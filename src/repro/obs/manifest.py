"""Run manifests: what produced this report, on what, at what cost.

Every benchmark report (``sensitivity`` / ``simspeed`` / ``serving``
and the telemetry capture) attaches a ``manifest`` block so a number
in ``bench_history/`` can always be traced back to the code revision,
jax version, backend, device topology, compile activity, and phase
wall-clock that produced it. All probes are guarded — a missing git
binary, a detached worktree, or an XLA backend without cost analysis
degrade to ``None`` fields, never to a failed benchmark run.

The regression gates (``repro.core.report.compare_*``) iterate only
the baseline's sections, so adding ``manifest`` to reports is
forward-compatible with committed baselines by construction.
"""
from __future__ import annotations

import contextlib
import os
import platform
import subprocess
import sys
import time
from typing import Dict, Optional


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    """HEAD commit sha of the repo containing this package, or None."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


class PhaseTimer:
    """Wall-clock accounting per named phase of a benchmark run.

    >>> timer = PhaseTimer()
    >>> with timer.phase("sweep"):
    ...     run_the_sweep()
    >>> timer.phases
    {'sweep': 1.234}

    Re-entering a phase name accumulates into it.
    """

    def __init__(self) -> None:
        self.phases: Dict[str, float] = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.phases[name] = self.phases.get(name, 0.0) \
                + (time.perf_counter() - t0)


def _compile_counts() -> Dict[str, int]:
    counts: Dict[str, int] = {}
    try:
        from repro.core import sweep
        counts["sweep"] = sweep.compile_count()
    except Exception:
        pass
    try:
        from repro.serving import engine
        counts["serving"] = engine.compile_count()
    except Exception:
        pass
    return counts


def serving_executable_costs() -> Dict[str, dict]:
    """XLA cost analysis (FLOPs / bytes accessed) per cached serving
    executable, keyed by a readable (policy, B, C, K) label."""
    costs: Dict[str, dict] = {}
    try:
        from repro.serving import engine
        executables = engine._EXECUTABLES
    except Exception:
        return costs
    for key, exe in executables.items():
        policy, _cfg, B, C, K = key[0], key[1], key[2], key[3], key[4]
        label = f"{policy}/B{B}/C{C}/K{K}"
        try:
            ca = exe.cost_analysis()
            if isinstance(ca, list):     # older jax returns [dict]
                ca = ca[0] if ca else {}
            costs[label] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            }
        except Exception:
            costs[label] = {"flops": None, "bytes_accessed": None}
    return costs


def run_manifest(phases: Optional[Dict[str, float]] = None,
                 extra: Optional[dict] = None) -> dict:
    """The manifest block attached to benchmark reports."""
    manifest: dict = {
        "git_sha": git_sha(),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "argv": list(sys.argv),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "compile_counts": _compile_counts(),
    }
    try:
        import jax
        manifest["jax_version"] = jax.__version__
        manifest["backend"] = jax.default_backend()
        manifest["device_count"] = jax.device_count()
    except Exception:
        manifest["jax_version"] = None
        manifest["backend"] = None
        manifest["device_count"] = None
    costs = serving_executable_costs()
    if costs:
        manifest["serving_executable_costs"] = costs
    if phases:
        manifest["phases_wall_s"] = {k: round(v, 6)
                                     for k, v in phases.items()}
    if extra:
        manifest.update(extra)
    return manifest
