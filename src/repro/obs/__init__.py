"""Observability exporters over ``repro.core.telemetry`` captures.

``repro.core`` owns the device-side capture (the static ``telemetry=``
argument on ``simulate`` / ``SweepGrid.run`` / ``serve_stream``); this
package owns everything host-side and downstream of it:

* :mod:`repro.obs.timeline` — :class:`SimTimeline` /
  :class:`ServeTimeline`: per-window counter series with exact
  conservation checks (window sums == run totals), window re-binning,
  and CSV/JSON series export for ``scripts/bench_trend.py``.
* :mod:`repro.obs.perfetto` — Chrome-trace-event JSON export (one
  track per core/shard/link, counter tracks for queue depth and hit
  rate), loadable in Perfetto / ``chrome://tracing``.
* :mod:`repro.obs.manifest` — run manifests (git sha, jax version,
  backend, device count, compile counts, XLA cost analysis, per-phase
  wall clock) attached to every benchmark report.

Layering: ``repro.obs`` imports from ``repro.core`` (the counter
registry) and ``repro.serving``; never the reverse at module scope —
``simulate``/``serve_stream`` import the timeline classes lazily
inside their telemetry branches.
"""
from repro.obs.manifest import PhaseTimer, run_manifest
from repro.obs.perfetto import trace_events, validate_trace, write_trace
from repro.obs.timeline import (ConservationError, ServeTimeline,
                                SimTimeline)

__all__ = [
    "SimTimeline", "ServeTimeline", "ConservationError",
    "trace_events", "validate_trace", "write_trace",
    "PhaseTimer", "run_manifest",
]
