"""Windowed counter timelines with exact conservation checks.

Both timelines store *cumulative* per-window snapshots in float64 /
int64 (leading axis = windows). Cumulative storage is what makes the
two guarantees exact rather than approximate:

* **conservation** — the final snapshot *is* the run total, and the
  per-window delta series telescopes back to it with no float rounding
  (every f32 counter value is exactly f64-representable, consecutive
  snapshot differences are exact, and summing them reproduces the
  final snapshot bit for bit — see :mod:`repro.core.telemetry`);
* **window invariance** — a timeline captured at window ``W`` re-binned
  by ``k`` (:meth:`SimTimeline.rebin`) equals the timeline captured at
  ``k*W`` exactly, because cumulative snapshots at shared round
  boundaries are identical regardless of stride (property-tested).

:meth:`SimTimeline.check` / :meth:`ServeTimeline.check` assert the
window sums against the corresponding ``SimResult`` / ``ServeResult``
totals and raise :class:`ConservationError` on any drift — the
telemetry smoke capture runs these in CI.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.telemetry import (Counter, SERVE_COUNTERS, SIM_COUNTERS,
                                  TelemetryConfig, hist_quantile,
                                  hist_quantile_edges, log2_edges)


class ConservationError(AssertionError):
    """A windowed counter series does not sum to its run total."""


def _registry(counters: Tuple[Counter, ...]) -> Dict[str, Counter]:
    return {c.name: c for c in counters}


_SIM_BY_NAME = _registry(SIM_COUNTERS)
_SERVE_BY_NAME = _registry(SERVE_COUNTERS)


def _widen(a: np.ndarray) -> np.ndarray:
    """Snapshot dtype widening: ints -> int64, floats -> float64."""
    a = np.asarray(a)
    if np.issubdtype(a.dtype, np.integer) or a.dtype == np.bool_:
        return a.astype(np.int64)
    return a.astype(np.float64)


def _check_eq(failures, name: str, got, want, atol: float = 0.0):
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    if got.shape != want.shape or not np.all(
            np.abs(got - want) <= atol):
        failures.append(f"{name}: window sum {got} != total {want}")


@dataclasses.dataclass
class _TimelineBase:
    """Shared mechanics; see :class:`SimTimeline` / :class:`ServeTimeline`."""
    window: int                        # rounds per window
    rounds: int                        # rounds covered
    cumulative: Dict[str, np.ndarray]  # {name: (n_windows, ...) snapshots}
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    _by_name: Dict[str, Counter] = dataclasses.field(
        default=None, repr=False, compare=False)  # set by subclass

    @property
    def n_windows(self) -> int:
        first = next(iter(self.cumulative.values()))
        return first.shape[0]

    @property
    def counter_names(self) -> Tuple[str, ...]:
        return tuple(self.cumulative)

    def counter(self, name: str) -> Counter:
        return self._by_name[name]

    def total(self, name: str) -> np.ndarray:
        """End-of-run total: the final cumulative snapshot."""
        return self.cumulative[name][-1]

    def series(self, name: str) -> np.ndarray:
        """Per-window values: deltas for cumulative counters, samples
        for gauges (leading axis = windows)."""
        snaps = self.cumulative[name]
        if not self._by_name[name].cumulative:
            return snaps
        zero = np.zeros_like(snaps[:1])
        return np.diff(np.concatenate([zero, snaps], axis=0), axis=0)

    def rebin(self, k: int) -> "_TimelineBase":
        """Coarsen to window ``k*W`` by subsampling cumulative snapshots.

        Exactly equals a capture taken at the coarser window (the
        snapshots at shared boundaries are identical), which is the
        invariance property the telemetry tests pin.
        """
        n = self.n_windows
        if k < 1 or n % k:
            raise ValueError(
                f"rebin factor {k} must divide the window count {n}")
        return dataclasses.replace(
            self, window=self.window * k,
            cumulative={name: snaps[k - 1::k]
                        for name, snaps in self.cumulative.items()})

    # ---- export ----------------------------------------------------
    def to_json_dict(self) -> dict:
        return {
            "kind": self.kind,
            "window": self.window,
            "rounds": self.rounds,
            "n_windows": self.n_windows,
            "meta": dict(self.meta),
            "counters": {
                name: {
                    "unit": c.unit, "axis": c.axis,
                    "cumulative": c.cumulative,
                    "series": self.series(name).tolist(),
                    "total": np.asarray(self.total(name)).tolist(),
                }
                for name, c in ((n, self._by_name[n])
                                for n in self.cumulative)
            },
        }

    def to_csv(self) -> str:
        """Long-form per-window series: one row per (window, counter,
        lane)."""
        lines = ["window,counter,axis,lane,value"]
        for name in self.cumulative:
            c = self._by_name[name]
            ser = self.series(name)
            flat = ser.reshape(ser.shape[0], -1)
            for w in range(flat.shape[0]):
                for lane in range(flat.shape[1]):
                    lines.append(f"{w},{name},{c.axis},{lane},"
                                 f"{flat[w, lane]!r}")
        return "\n".join(lines) + "\n"

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json_dict(), f, indent=1, sort_keys=True)

    def write_csv(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_csv())


@dataclasses.dataclass
class SimTimeline(_TimelineBase):
    """Windowed counter timeline of one simulator run.

    Built from the cumulative snapshot stack the telemetry-enabled
    ``lax.scan`` emits (``repro.core.simulator._sim_core``); counters
    follow :data:`repro.core.telemetry.SIM_COUNTERS`.
    """
    kind = "sim"

    def __post_init__(self):
        if self._by_name is None:
            self._by_name = _SIM_BY_NAME

    @classmethod
    def from_snapshots(cls, snaps, telemetry: TelemetryConfig, *,
                       rounds: int, meta: Optional[dict] = None
                       ) -> "SimTimeline":
        """``snaps = {"stats": {...}, "noc": {...}}``, each leaf with a
        leading window axis (device output of the telemetry scan)."""
        cumulative: Dict[str, np.ndarray] = {}
        for c in SIM_COUNTERS:
            if c.field.startswith("noc."):
                leaf = snaps["noc"][c.field[len("noc."):]]
            elif c.field in snaps["stats"]:
                leaf = snaps["stats"][c.field]
            else:            # lat_hist with histograms off
                continue
            cumulative[c.name] = _widen(leaf)
        return cls(window=telemetry.window, rounds=rounds,
                   cumulative=cumulative, meta=dict(meta or {}))

    # ---- histogram -------------------------------------------------
    @property
    def hist(self) -> Optional[np.ndarray]:
        """Final log2-bucketed L1-complete latency histogram."""
        if "lat_hist" not in self.cumulative:
            return None
        return self.total("lat_hist")

    @property
    def hist_edges(self) -> Optional[np.ndarray]:
        return None if self.hist is None else log2_edges(self.hist.size)

    def hist_percentile(self, q: float) -> float:
        """Bucket-exact quantile (conservative upper edge) of the
        L1-complete latency distribution."""
        if self.hist is None:
            raise ValueError("telemetry was captured without histograms")
        return hist_quantile_edges(self.hist, q, self.hist_edges)

    # ---- conservation ----------------------------------------------
    def check(self, result) -> "SimTimeline":
        """Assert window sums == ``SimResult`` totals (exact).

        Raises :class:`ConservationError` naming every violated
        counter; returns ``self`` so captures can be checked inline.
        """
        failures: list = []
        sums = {name: self.series(name).sum(axis=0)
                for name in self.cumulative
                if self._by_name[name].cumulative}
        # telescoping: window sums must equal the final snapshot
        for name, s in sums.items():
            _check_eq(failures, f"{name} (telescoping)", s,
                      self.total(name))
        _check_eq(failures, "l2_accesses", sums["l2_accesses"],
                  result.l2_accesses)
        _check_eq(failures, "dram", sums["dram"], result.dram_accesses)
        _check_eq(failures, "noc_flits", sums["noc_flits"],
                  result.noc_flits)
        _check_eq(failures, "cycles(max)", sums["cycles"].max(),
                  result.cycles)
        req = float(sums["requests"])
        if req:
            _check_eq(failures, "local_hit_rate",
                      float(sums["local_hits"]) / req,
                      result.local_hit_rate)
            _check_eq(failures, "remote_hit_rate",
                      float(sums["remote_hits"]) / req,
                      result.remote_hit_rate)
        latn = float(sums["l1_lat_n"])
        if latn:
            _check_eq(failures, "l1_latency",
                      float(sums["l1_lat_sum"]) / latn,
                      result.l1_latency)
        _check_eq(failures, "noc.injected", sums["noc.injected"],
                  result.noc.flits_injected)
        _check_eq(failures, "noc.delivered", sums["noc.delivered"],
                  result.noc.flits_delivered)
        for a, app in enumerate(result.per_app):
            _check_eq(failures, f"app_local[{a}]",
                      sums["app_local"][a], app.local_hits)
            _check_eq(failures, f"app_remote[{a}]",
                      sums["app_remote"][a], app.remote_hits)
            _check_eq(failures, f"app_lat_sum[{a}]",
                      sums["app_lat_sum"][a], app.l1_lat_sum)
        if self.hist is not None:
            _check_eq(failures, "lat_hist(sum)",
                      int(self.hist.sum()), latn)
        if failures:
            raise ConservationError(
                "sim timeline conservation violated:\n  "
                + "\n  ".join(failures))
        return self


@dataclasses.dataclass
class ServeTimeline(_TimelineBase):
    """Windowed counter timeline of one serving-engine replay.

    Window unit is *admission rounds* (``B`` slots per shard each);
    counters follow :data:`repro.core.telemetry.SERVE_COUNTERS`. Built
    host-side from the per-sub-round emission grids the engine already
    streams back, plus the device-side latency bincount (``hist``).
    A ragged final window is allowed (host aggregation has no static
    shape constraint).
    """
    kind = "serve"
    hist: Optional[np.ndarray] = None   # (bins,) int64, 1 cycle/bucket
    hist_exact: bool = False            # quantiles == np.percentile

    def __post_init__(self):
        if self._by_name is None:
            self._by_name = _SERVE_BY_NAME

    @classmethod
    def from_grids(cls, *, window: int, slots: int,
                   served: np.ndarray, nl: np.ndarray, nr: np.ndarray,
                   nc: np.ndarray, lat: np.ndarray,
                   pm_rounds: np.ndarray, cycles_rounds: np.ndarray,
                   tenant: np.ndarray, n_tenants: int,
                   hist: Optional[np.ndarray] = None,
                   hist_exact: bool = False,
                   meta: Optional[dict] = None) -> "ServeTimeline":
        """Aggregate (T, C) sub-round grids into per-window cumulative
        snapshots. ``pm_rounds`` / ``cycles_rounds`` are per-admission-
        round scalars (length ``T // slots``)."""
        T, C = served.shape
        n_adm = T // slots
        W = min(window, n_adm)
        bounds = np.arange(0, n_adm, W)          # ragged tail allowed
        sub_bounds = bounds * slots

        def win_sum(grid, dtype):
            g = np.asarray(grid, dtype)
            return np.add.reduceat(g, sub_bounds, axis=0)

        def win_sum_rounds(per_round, dtype):
            g = np.asarray(per_round, dtype)
            return np.add.reduceat(g, bounds, axis=0)

        widx = np.repeat(np.arange(bounds.size),
                         np.diff(np.append(sub_bounds, T)))  # (T,)

        def per_tenant(weights, dtype=np.int64):
            out = np.zeros((bounds.size, n_tenants), dtype)
            w2 = np.broadcast_to(widx[:, None], served.shape)[served]
            np.add.at(out, (w2, np.asarray(tenant)[served]),
                      np.asarray(weights, dtype)[served])
            return out

        deltas = {
            "admitted": win_sum(served, np.int64),
            "local_hits": win_sum(nl, np.int64),
            "remote_hits": win_sum(nr, np.int64),
            "recomputed": win_sum(nc, np.int64),
            "latency_sum": win_sum(lat, np.float64),
            "cycles": win_sum_rounds(cycles_rounds, np.float64),
            "probe_messages": win_sum_rounds(pm_rounds, np.int64),
            "tenant_requests": per_tenant(np.ones_like(served, np.int64)),
            "tenant_blocks": per_tenant(
                np.asarray(nl, np.int64) + np.asarray(nr, np.int64)
                + np.asarray(nc, np.int64)),
        }
        cumulative = {name: np.cumsum(d, axis=0)
                      for name, d in deltas.items()}
        return cls(window=W, rounds=n_adm, cumulative=cumulative,
                   meta=dict(meta or {}),
                   hist=None if hist is None else _widen(hist),
                   hist_exact=hist_exact)

    def hist_percentile(self, q: float) -> float:
        """Quantile from the value-resolved latency histogram —
        bit-identical to ``np.percentile`` when ``hist_exact``."""
        if self.hist is None:
            raise ValueError("telemetry was captured without histograms")
        return hist_quantile(self.hist, q)

    def check(self, result) -> "ServeTimeline":
        """Assert window sums == ``ServeResult`` totals (exact)."""
        failures: list = []
        sums = {name: self.series(name).sum(axis=0)
                for name in self.cumulative}
        for name, s in sums.items():
            _check_eq(failures, f"{name} (telescoping)", s,
                      self.total(name))
        _check_eq(failures, "admitted", sums["admitted"].sum(),
                  result.n_requests)
        _check_eq(failures, "local_hits", sums["local_hits"].sum(),
                  result.local_hits)
        _check_eq(failures, "remote_hits", sums["remote_hits"].sum(),
                  result.remote_hits)
        _check_eq(failures, "recomputed", sums["recomputed"].sum(),
                  result.recomputed_blocks)
        _check_eq(failures, "probe_messages", sums["probe_messages"],
                  result.probe_messages)
        _check_eq(failures, "cycles", sums["cycles"], result.cycles)
        _check_eq(failures, "latency_sum", sums["latency_sum"].sum(),
                  result.tenant_latency_sum.sum())
        _check_eq(failures, "tenant_requests", sums["tenant_requests"],
                  result.tenant_requests)
        _check_eq(failures, "tenant_blocks", sums["tenant_blocks"],
                  result.tenant_blocks)
        if self.hist is not None:
            _check_eq(failures, "lat_hist(sum)", int(self.hist.sum()),
                      int(np.asarray(result.served).sum()))
        if failures:
            raise ConservationError(
                "serving timeline conservation violated:\n  "
                + "\n  ".join(failures))
        return self
