"""Chrome-trace-event export of telemetry timelines.

Emits the JSON object format (``{"traceEvents": [...]}``) that
Perfetto and ``chrome://tracing`` load directly:

* one **thread track per core** (simulator) or **per shard** (serving
  engine) carrying one complete-event span per window, whose duration
  is that lane's accumulated work in the window (per-core round cost /
  per-shard latency sum) — lanes that fall behind the global clock
  show idle gaps;
* one **thread track per active link** under a ``noc`` process with
  per-window flit spans (simulator captures with a non-ideal NoC);
* **counter tracks** (``"ph": "C"``) for hit rate, queue depth,
  L2/DRAM traffic, and probe messages, sampled at window boundaries.

The global timebase is the run's modeled clock in cycles (mapped 1:1
onto trace microseconds): window ``w`` spans
``[cum_cycles[w-1], cum_cycles[w])`` where ``cum_cycles`` is the
cumulative max-over-lanes cycle counter — so wall layout matches the
model's own notion of time, not the host's.

:func:`validate_trace` is the schema check CI (and the tier-1 tests)
run against generated and committed traces.
"""
from __future__ import annotations

import json
from typing import List, Optional

import numpy as np

from repro.obs.timeline import ServeTimeline, SimTimeline

_PHASES = {"M", "C", "X"}


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> List[dict]:
    evs = [{"ph": "M", "name": "process_name", "pid": pid,
            "args": {"name": name}}]
    if tid is not None:
        evs.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": tid, "args": {"name": tname}})
    return evs


def _counter(name: str, pid: int, ts: float, value: float) -> dict:
    return {"ph": "C", "name": name, "pid": pid, "ts": float(ts),
            "args": {"value": float(value)}}


def _span(name: str, pid: int, tid: int, ts: float, dur: float) -> dict:
    return {"ph": "X", "name": name, "pid": pid, "tid": tid,
            "ts": float(ts), "dur": float(max(dur, 0.0))}


def _lane_spans(events, pid, lane_work, window_starts, label):
    """One span per (lane, window): duration = the lane's work."""
    n_w, n_lanes = lane_work.shape
    for lane in range(n_lanes):
        for w in range(n_w):
            dur = float(lane_work[w, lane])
            if dur > 0.0:
                events.append(_span(f"{label} w{w}", pid, lane,
                                    window_starts[w], dur))


def sim_trace_events(tl: SimTimeline) -> dict:
    """Trace-event JSON for one simulator timeline."""
    events: List[dict] = []
    cycles = tl.series("cycles")                       # (nW, C)
    n_w, n_cores = cycles.shape
    clock = np.concatenate([[0.0],
                            np.cumsum(cycles.max(axis=1))])  # (nW+1,)
    starts, ends = clock[:-1], clock[1:]

    pid_cores, pid_counters, pid_links = 1, 2, 3
    events += _meta(pid_cores, "cores")
    for c in range(n_cores):
        events += _meta(pid_cores, "cores", c, f"core {c}")[1:]
    _lane_spans(events, pid_cores, cycles, starts, "rounds")

    events += _meta(pid_counters, "counters")
    req = tl.series("requests")
    local, remote = tl.series("local_hits"), tl.series("remote_hits")
    l2, dram = tl.series("l2_accesses"), tl.series("dram")
    queue = (tl.series("noc.queue") if "noc.queue" in tl.cumulative
             else None)
    for w in range(n_w):
        r = float(req[w])
        hit = (float(local[w] + remote[w]) / r) if r else 0.0
        events.append(_counter("l1_hit_rate", pid_counters, ends[w], hit))
        events.append(_counter("l2_accesses", pid_counters, ends[w],
                               float(l2[w])))
        events.append(_counter("dram", pid_counters, ends[w],
                               float(dram[w])))
        if queue is not None:
            events.append(_counter("noc_queue_depth", pid_counters,
                                   ends[w], float(queue[w].sum())))

    link_flits = tl.series("noc.link_flits")           # (nW, L)
    active = np.flatnonzero(link_flits.sum(axis=0) > 0)
    if active.size:
        events += _meta(pid_links, "noc")
        for li in active:
            events += _meta(pid_links, "noc", int(li),
                            f"link {int(li)}")[1:]
        _lane_spans(events, pid_links, link_flits[:, active],
                    starts, "flits")

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"kind": tl.kind, "window": tl.window,
                          "rounds": tl.rounds, **{
                              k: v for k, v in tl.meta.items()
                              if isinstance(v, (str, int, float))}}}


def serve_trace_events(tl: ServeTimeline) -> dict:
    """Trace-event JSON for one serving-engine timeline."""
    events: List[dict] = []
    cycles = tl.series("cycles")                       # (nW,)
    lat = tl.series("latency_sum")                     # (nW, C)
    n_w = cycles.shape[0]
    clock = np.concatenate([[0.0], np.cumsum(cycles)])
    starts, ends = clock[:-1], clock[1:]

    pid_shards, pid_counters = 1, 2
    events += _meta(pid_shards, "shards")
    for c in range(lat.shape[1]):
        events += _meta(pid_shards, "shards", c, f"shard {c}")[1:]
    _lane_spans(events, pid_shards, lat, starts, "serve")

    events += _meta(pid_counters, "counters")
    adm = tl.series("admitted").sum(axis=1)
    hits = (tl.series("local_hits") + tl.series("remote_hits")) \
        .sum(axis=1)
    blocks = hits + tl.series("recomputed").sum(axis=1)
    pm = tl.series("probe_messages")
    for w in range(n_w):
        rate = float(hits[w]) / float(blocks[w]) if blocks[w] else 0.0
        events.append(_counter("hit_rate", pid_counters, ends[w], rate))
        events.append(_counter("admitted", pid_counters, ends[w],
                               float(adm[w])))
        events.append(_counter("probe_messages", pid_counters, ends[w],
                               float(pm[w])))

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"kind": tl.kind, "window": tl.window,
                          "rounds": tl.rounds, **{
                              k: v for k, v in tl.meta.items()
                              if isinstance(v, (str, int, float))}}}


def trace_events(tl) -> dict:
    """Dispatch on timeline kind."""
    if isinstance(tl, SimTimeline):
        return sim_trace_events(tl)
    if isinstance(tl, ServeTimeline):
        return serve_trace_events(tl)
    raise TypeError(f"not a telemetry timeline: {type(tl).__name__}")


def write_trace(path: str, tl, manifest: Optional[dict] = None) -> dict:
    """Export ``tl`` as Chrome-trace-event JSON at ``path``.

    Validates the object before writing; attaches the run manifest
    under ``otherData.manifest`` when given. Returns the trace dict.
    """
    obj = trace_events(tl)
    if manifest is not None:
        obj["otherData"]["manifest"] = manifest
    validate_trace(obj)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
    return obj


def validate_trace(obj) -> None:
    """Raise ``ValueError`` unless ``obj`` is valid Chrome-trace-event
    JSON (object format, known phases, well-typed fields)."""
    problems: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError(
            "not a Chrome-trace-event object: missing 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty list")
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            problems.append(f"{where}: 'name' must be a string")
        if not isinstance(ev.get("pid"), int):
            problems.append(f"{where}: 'pid' must be an int")
        if ph in ("C", "X") and not isinstance(
                ev.get("ts"), (int, float)):
            problems.append(f"{where}: '{ph}' needs numeric 'ts'")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev["dur"] < 0:
                problems.append(
                    f"{where}: 'X' needs non-negative numeric 'dur'")
            if not isinstance(ev.get("tid"), int):
                problems.append(f"{where}: 'X' needs int 'tid'")
        if ph in ("C", "M"):
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                problems.append(
                    f"{where}: '{ph}' needs a non-empty 'args' object")
            elif ph == "C" and not all(
                    isinstance(v, (int, float)) for v in args.values()):
                problems.append(
                    f"{where}: 'C' args values must be numeric")
    try:
        json.dumps(obj)
    except (TypeError, ValueError) as e:
        problems.append(f"not JSON-serializable: {e}")
    if problems:
        raise ValueError("invalid trace-event JSON:\n  "
                         + "\n  ".join(problems[:20]))
