"""Vectorized, jitted ATA serving engine with batched admission.

The production-scale replacement for the Python-loop oracle
(``repro.serving.ref``): a :class:`~repro.core.trace.serving.
RequestStream` grid — ``B = stream.slots`` admission slots per shard
per round — is replayed by ``lax.scan``, so millions of requests run
in vectorized steps with no per-request Python.

Round semantics (the oracle's ``run_stream`` is the bit-exact
reference):

1. **Probe** — every arriving request compares its block chain against
   the sub-round-start replicated directory of all shards. Under
   ``ata`` this is the aggregated-tag-array compare the paper builds
   in hardware; the ``ata_tag_probe`` Pallas kernel is a selectable
   backend for it (``lax`` is the fused-XLA default, mirroring
   ``repro.core.probe.PROBE_BACKENDS``).
2. **Walk** — each request reuses its leading hits (prefix semantics);
   reuse of an own-shard block is revalidated against the *live* local
   directory (this shard's own replication inserts can evict a block
   mid-walk), remote presence is vouched for by the probe (remote
   shards never mutate each other's arrays — the local-write rule).
   Under ``ata`` a remote hit replicates into the local directory
   (paper Fig 7(a)); after the first failure all remaining blocks
   recompute and seal locally.
3. **Price** — remote fetches become :class:`~repro.core.noc.
   NocTraffic` (``flits_per_block`` flits from owner to requester)
   through a pluggable :class:`~repro.core.noc.NocModel` whose state
   carries across rounds (crossbar backpressure works); per-request
   latency folds hit/fetch/recompute terms, the broadcast policy's
   probe round trip, and the NoC delay + occupancy.

**Batched round contract** (``B > 1``): each scan step runs ``B``
sequential *sub-rounds* — an inner ``lax.scan`` over the slot axis —
so slot ``b`` probes a directory that already contains slots
``< b``'s replication inserts and the LRU clock ticks once per
sub-round (``t*B + b + 1``). That makes every hit/probe/fetch counter
bit-identical to the slot-sequentialized ``B=1`` replay *by
construction* (property-tested), while the throughput model charges
one round of critical-path latency (``max`` over all ``B×C``
requests) per ``B`` admissions and routes the whole round's remote
fetches through **one** NoC round (slot-major ``B·C·K`` traffic, so
the crossbar's ``group_prefix_sum`` port arbitration orders the
slots' flits exactly like the architecture policies order ports).

Engine internals (the measured ~2x single-round speedup vs the
pre-batching engine):

* the directory is one packed ``(C, S, W, 2)`` int32 array holding
  ``[tag, last-touch]`` lanes — validity is ``tag != 0`` (stream
  hashes are >= 1 by contract), halving the scatter count per walk
  step and shrinking the donated carry to ``{dir, noc, t}``;
* way selection is a single packed-key ``min`` (present < free < LRU,
  ties to the lowest way — first-occurrence semantics identical to
  the previous ``argmax``/``argmin`` chain);
* counters, shard load, and tenant attribution are *derived from the
  emitted per-sub-round outputs* after the scan instead of being
  carried through it, and the per-request latency grid streams back
  to the host where the final sums run in float64/int64 — the int32 /
  f32 overflow-headroom story for nightly-scale runs (the remaining
  device-side int32s — the LRU clock and the packed way key — are
  guarded at config time by :func:`_check_headroom`);
* replay is chunked: fixed-shape chunks of ``_CHUNK_SUBROUNDS``
  sub-rounds run through a **keyed executable cache**
  (:data:`_EXECUTABLES`, keyed by policy x config x slots x stream
  geometry) with ``donate_argnums`` on the carry, so the
  ``{8,16} shards x mixes x 3 policies`` benchmark grid compiles one
  executable per (policy, backend, B) no matter how many rounds each
  cell replays.

Policies: ``private`` (local-only), ``broadcast`` (probe all shards on
local miss — the oracle's ``remote``), ``ata`` (replicated directory,
zero probe messages). The oracle-only ``decoupled`` policy has no
engine analog (its home hash needs int64).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import GpuGeometry
from repro.core.noc import NocTraffic, get_noc, init_noc_state
from repro.core.telemetry import (TelemetryConfig, hist_quantile,
                                  serving_hist_bins)
from repro.kernels.ata_tag_probe import ata_tag_probe

SERVING_POLICIES = ("private", "broadcast", "ata")

#: Directory-probe backends: fused XLA gather/compare (default), the
#: ``ata_tag_probe`` Pallas kernel compiled by Mosaic (TPU), and the
#: same kernel interpreted (validation off-TPU).
SERVING_PROBE_BACKENDS = ("lax", "pallas", "pallas_interpret")

#: Sub-rounds per compiled chunk. Fixed so every replay of the same
#: (policy, backend, slots, stream geometry) reuses one executable
#: regardless of total rounds; must be divisible by every supported
#: ``slots`` value (powers of two up to ``_MAX_SLOTS`` all divide it).
_CHUNK_SUBROUNDS = 512


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Static engine configuration (hashable: one executable per value).

    The directory mirrors :class:`~repro.serving.ref.AtaCacheConfig`
    (``n_shards`` comes from the stream). Timing terms are abstract
    serving cycles; the NoC scalars feed the
    :class:`~repro.core.geometry.GpuGeometry` the interconnect models
    price traffic with.
    """
    n_sets: int = 64
    n_ways: int = 8
    # --- latency model (cycles per block / per request) -------------
    lat_hit: float = 1.0        # local pool read per block
    lat_fetch: float = 4.0      # remote fetch base per block (+ NoC)
    lat_recompute: float = 40.0  # prefill recompute per block
    lat_probe_rtt: float = 6.0  # broadcast probe round trip per request
    # --- interconnect ----------------------------------------------
    flits_per_block: int = 4
    noc: str = "ideal"
    noc_bw: float = 16.0
    # --- probe backend ---------------------------------------------
    probe_backend: str = "lax"

    def __post_init__(self):
        if self.noc not in ("ideal", "crossbar", "ring"):
            get_noc(self.noc)   # raises with the registered list
        if self.probe_backend not in SERVING_PROBE_BACKENDS:
            raise ValueError(
                f"probe_backend must be one of {SERVING_PROBE_BACKENDS},"
                f" got {self.probe_backend!r}")

    def geometry(self, n_shards: int) -> GpuGeometry:
        """The one-cluster geometry the NoC models price traffic with."""
        return GpuGeometry(n_cores=n_shards, cluster_size=n_shards,
                           l1_sets=self.n_sets, l1_ways=self.n_ways,
                           flits_per_line=self.flits_per_block,
                           noc_bw=self.noc_bw)


class ServeResult(NamedTuple):
    """Aggregate + per-sub-round outputs of one engine replay."""
    policy: str
    n_requests: int
    local_hits: int
    remote_hits: int
    recomputed_blocks: int
    probe_messages: int
    remote_fetch_blocks: int
    directory_sync_entries: int
    shard_load: np.ndarray          # (C,) reuse serves per shard
    latency: np.ndarray             # (T, C) f32 modeled request latency
    served: np.ndarray              # (T, C) bool request present
    tenants: Tuple[str, ...]
    tenant_requests: np.ndarray     # (n_tenants,)
    tenant_hit_blocks: np.ndarray
    tenant_blocks: np.ndarray
    tenant_latency_sum: np.ndarray  # (n_tenants,) f64
    cycles: float                   # sum of per-round critical paths
    slots: int                      # admissions per shard per round (B)
    noc_injected: float
    noc_delivered: float
    noc_queued: float
    #: value-resolved modeled-latency bincount (telemetry runs only)
    lat_hist: Optional[np.ndarray] = None
    #: histogram quantiles reproduce np.percentile exactly (integral
    #: cost model + ideal NoC)
    hist_exact: bool = False

    @property
    def hit_rate(self) -> float:
        tot = self.local_hits + self.remote_hits + self.recomputed_blocks
        return (self.local_hits + self.remote_hits) / max(tot, 1)

    @property
    def request_latencies(self) -> np.ndarray:
        return self.latency[self.served]

    def latency_percentile(self, q: float) -> float:
        if self.lat_hist is not None and self.hist_exact:
            # exact quantile read from the histogram — bit-identical
            # to np.percentile over the materialized latency array
            return hist_quantile(self.lat_hist, q) \
                if self.lat_hist.sum() else 0.0
        lat = self.request_latencies
        return float(np.percentile(lat, q)) if lat.size else 0.0

    @property
    def p50_latency(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99_latency(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def requests_per_kcycle(self) -> float:
        """Modeled throughput (requests per 1000 modeled cycles).

        At ``slots = B`` the engine charges one round of critical-path
        latency per ``B`` admissions, so this is where batched
        admission pays off in the model — the machine-portable number
        the CI throughput-ratio gate compares across B.
        """
        return 1e3 * self.n_requests / max(self.cycles, 1e-9)

    @property
    def load_imbalance(self) -> float:
        m = self.shard_load.mean()
        return float(self.shard_load.max() / m) if m else 0.0


def _probe_all(tags, h, set_idx, *, backend):
    """(C, K, C_dir) hits of every request block vs every directory.

    Validity is implied by the packed-directory contract: sealed tags
    are >= 1 and empty ways are 0, while invalid block lanes carry
    hash 0 — so ``tag == hash != 0`` is the whole hit predicate.
    """
    C, K = h.shape
    if backend == "lax":
        g_t = tags[:, set_idx, :]                   # (C_dir, C, K, W)
        hits = ((g_t == h[None, :, :, None]) & (g_t != 0)).any(-1)
        return jnp.transpose(hits, (1, 2, 0))       # (C, K, C_dir)
    R = C * K
    bc = 8 if C % 8 == 0 else C
    hits, _ = ata_tag_probe(
        set_idx.reshape(R), h.reshape(R), tags, tags != 0, br=R, bc=bc,
        interpret=True if backend == "pallas_interpret" else None)
    return hits.reshape(C, K, C)


def _make_chunk_fn(policy: str, cfg: ServingConfig, B: int, C: int,
                   K: int,
                   telemetry: Optional[TelemetryConfig] = None):
    """Build the per-chunk scan body for one executable-cache key.

    The returned function replays ``steps`` admission rounds of ``B``
    sub-rounds each: ``(carry, xs) -> (carry, outs)`` with
    ``carry = {dir, noc, t}`` (donated) and ``outs`` the per-chunk
    emissions the host reduces in wide arithmetic.

    ``telemetry`` (static) additionally emits a per-chunk
    value-resolved latency bincount (``hist``, one int32 bucket per
    modeled cycle up to the :func:`_check_headroom` bound, last bucket
    absorbs non-ideal-NoC overflow) and the per-admission-round probe
    message series (``pm_steps``) for the windowed timeline. The
    ``None`` default traces exactly the pre-telemetry chunk program.
    """
    S, W = cfg.n_sets, cfg.n_ways
    geom = cfg.geometry(C)
    noc = get_noc(cfg.noc)
    i32 = jnp.int32
    f32 = jnp.float32
    cidx = jnp.arange(C, dtype=i32)
    karange = jnp.arange(K)
    warange = jnp.arange(W, dtype=i32)

    def sub_round(c, xb):
        """One admission slot across all shards (a B=1 round)."""
        dirr, t = c
        vr, h, nb = xb                   # (C,), (C, K), (C,)
        clock = t + 1
        set_idx = (h % S).astype(i32)
        tags = dirr[..., 0]

        hits = _probe_all(tags, h, set_idx,
                          backend=cfg.probe_backend)  # (C, K, C_dir)
        local_hit = hits[cidx[:, None], karange[None, :], cidx[:, None]]
        bvalid = (karange[None, :] < nb[:, None]) & vr[:, None]
        if policy == "private":
            hit = local_hit
            owner = jnp.broadcast_to(cidx[:, None], (C, K))
        else:
            hit = hits.any(-1)
            owner = jnp.where(local_hit, cidx[:, None],
                              jnp.argmax(hits, axis=-1).astype(i32))
        miss_bcast = bvalid & ~local_hit
        if policy == "broadcast":
            # one broadcast per locally-missing block of the chain
            pm = jnp.sum(miss_bcast.astype(i32)) * (C - 1)
            rtt = miss_bcast.any(-1)
        else:
            pm = i32(0)
            rtt = jnp.zeros((C,), jnp.bool_)

        alive = vr
        n_local = jnp.zeros((C,), i32)
        n_remote = jnp.zeros((C,), i32)
        n_recomp = jnp.zeros((C,), i32)
        srcs, reus, rems = [], [], []
        for k in range(K):               # static unroll over the chain
            bv = bvalid[:, k]
            hh, si = h[:, k], set_idx[:, k]
            ow = owner[:, k]
            row = dirr[cidx, si]                         # (C, W, 2)
            row_t, row_l = row[..., 0], row[..., 1]
            present_way = (row_t == hh[:, None]) & (row_t != 0)
            # packed way key: present (-1) < free (0) < LRU age, ties
            # to the lowest way — first-occurrence order, identical to
            # an argmax(present)/argmax(free)/argmin(last) chain
            sel = jnp.where(present_way, -1,
                            jnp.where(row_t == 0, 0, row_l))
            pk = ((sel + 1) * W + warange).min(-1)
            way = (pk % W).astype(i32)
            present_self = pk < W
            # own-shard reuse revalidates live; remote is probe-vouched
            ok = (ow != cidx) | present_self
            reused = alive & bv & hit[:, k] & ok
            recomp = bv & ~reused
            alive = alive & (~bv | reused)
            local = reused & (ow == cidx)
            remote = reused & ~local
            n_local += local
            n_remote += remote
            n_recomp += recomp
            do_insert = (recomp | remote) if policy == "ata" else recomp
            row_sel = jnp.where(do_insert, cidx, C)      # OOB -> drop
            dirr = dirr.at[row_sel, si, way].set(
                jnp.stack([hh, jnp.full_like(hh, clock)], -1),
                mode="drop")
            srcs.append(ow)
            reus.append(reused)
            rems.append(remote)

        base = (cfg.lat_hit * n_local + cfg.lat_fetch * n_remote
                + cfg.lat_recompute * n_recomp).astype(f32)
        ys = dict(nl=n_local, nr=n_remote, nc=n_recomp, base=base,
                  rtt=rtt, pm=pm,
                  src=jnp.stack(srcs, axis=1),           # (C, K)
                  reu=jnp.stack(reus, axis=1),
                  rem=jnp.stack(rems, axis=1))
        return (dirr, clock), ys

    def step(carry, x):
        """One admission round: B sequential sub-rounds, one NoC round."""
        vr_b, h_b, nb_b = x              # (B, C), (B, C, K), (B, C)
        (dirr, t), ys = jax.lax.scan(
            sub_round, (carry["dir"], carry["t"]), (vr_b, h_b, nb_b))

        # one NoC round carries the whole admission round's fetches,
        # slot-major so port arbitration (crossbar group_prefix_sum)
        # orders earlier slots' flits first
        src = ys["src"].reshape(-1)                      # (B*C*K,)
        traffic = NocTraffic(
            src=src, dst=jnp.tile(jnp.repeat(cidx, K), B),
            cluster=jnp.zeros_like(src),
            flits=jnp.full((B * C * K,), float(cfg.flits_per_block),
                           f32),
            mask=ys["rem"].reshape(-1))
        transit = noc.transit(geom, carry["noc"], traffic)
        noc_extra = (transit.delay + transit.occupancy) \
            .reshape(B, C, K).sum(-1)

        lat = ys["base"] + noc_extra
        lat += cfg.lat_probe_rtt * ys["rtt"].astype(f32)
        lat = jnp.where(vr_b, lat, 0.0)

        new = dict(dir=dirr, noc=transit.state, t=t)
        outs = dict(lat=lat, nl=ys["nl"], nr=ys["nr"], nc=ys["nc"],
                    pm=ys["pm"].sum(),
                    slidx=jnp.where(ys["reu"], ys["src"], C))
        return new, outs

    def chunk(carry, xs):
        carry, ys = jax.lax.scan(step, carry, xs)
        # per-chunk shard-load reduction: one scatter over the chunk's
        # reused blocks (int32 is safe — a chunk is bounded)
        shard_load = jnp.zeros((C + 1,), i32) \
            .at[ys.pop("slidx").reshape(-1)].add(1)[:C]
        outs = dict(ys, pm=ys["pm"].sum(), shard_load=shard_load)
        if telemetry is not None:
            outs["pm_steps"] = ys["pm"]              # (steps,)
            if telemetry.histograms:
                nb = serving_hist_bins(_max_latency(cfg, K))
                idx = jnp.clip(ys["lat"], 0.0, nb - 1).astype(i32)
                outs["hist"] = jnp.zeros((nb,), i32) \
                    .at[idx.reshape(-1)] \
                    .add(xs[0].reshape(-1).astype(i32))
        return carry, outs

    return chunk


def _max_latency(cfg: ServingConfig, K: int) -> float:
    """Per-request modeled-latency bound under an ideal NoC."""
    return K * max(cfg.lat_hit, cfg.lat_fetch, cfg.lat_recompute) \
        + cfg.lat_probe_rtt


def _integral_cost_model(cfg: ServingConfig) -> bool:
    """True when every latency term is a whole number of cycles and
    the NoC adds none — the regime where the value-resolved histogram
    reconstructs ``np.percentile`` exactly."""
    return cfg.noc == "ideal" and all(
        float(v).is_integer() for v in (cfg.lat_hit, cfg.lat_fetch,
                                        cfg.lat_recompute,
                                        cfg.lat_probe_rtt))


#: Keyed executable cache: (policy, cfg, slots, C, K, steps,
#: telemetry) -> the donated-carry chunk executable. All replays
#: sharing a key — every cell of the benchmark grid with the same
#: policy/backend/B/geometry, any number of rounds — reuse one
#: compiled chunk; ``telemetry=None`` keys the pre-telemetry programs.
_EXECUTABLES: Dict[tuple, jax.stages.Compiled] = {}


def _get_executable(policy: str, cfg: ServingConfig, B: int, C: int,
                    K: int, steps: int,
                    telemetry: Optional[TelemetryConfig] = None):
    key = (policy, cfg, B, C, K, steps, telemetry)
    exe = _EXECUTABLES.get(key)
    if exe is None:
        fn = jax.jit(_make_chunk_fn(policy, cfg, B, C, K, telemetry),
                     donate_argnums=(0,))
        sds = jax.ShapeDtypeStruct
        i32, f32 = jnp.int32, jnp.float32
        noc0 = init_noc_state(get_noc(cfg.noc).n_links(cfg.geometry(C)))
        carry_abs = dict(
            dir=sds((C, cfg.n_sets, cfg.n_ways, 2), i32),
            noc=jax.tree.map(lambda a: sds(a.shape, a.dtype), noc0),
            t=sds((), i32))
        xs_abs = (sds((steps, B, C), jnp.bool_),
                  sds((steps, B, C, K), i32),
                  sds((steps, B, C), i32))
        exe = fn.lower(carry_abs, xs_abs).compile()
        _EXECUTABLES[key] = exe
    return exe


def _check_headroom(policy: str, cfg: ServingConfig, T: int, C: int,
                    K: int) -> None:
    """Config-time overflow guards for the device-side narrow types.

    The scan carry keeps only int32 state (the LRU clock and the
    packed way-selection key derived from it); per-chunk emissions are
    int32/f32 but bounded by the fixed chunk shape, and the final
    counter / latency / cycle accumulation runs on the host in int64 /
    float64 — the widened accumulators for nightly-scale runs (>= 1M
    requests x per-request latency approaches 2^31 in 32-bit).
    """
    lim = np.iinfo(np.int32).max
    # LRU clock ticks once per sub-round; way selection packs it as
    # (last + 1) * n_ways + way
    if (T + 2) * cfg.n_ways >= lim:
        raise ValueError(
            f"{T} sub-rounds x {cfg.n_ways} ways overflows the int32 "
            f"packed LRU key; shard the replay below "
            f"{lim // cfg.n_ways - 2} rounds")
    # per-chunk probe-message sum (broadcast worst case) stays int32
    if policy == "broadcast" \
            and _CHUNK_SUBROUNDS * C * K * max(C - 1, 1) >= lim:
        raise ValueError(
            f"broadcast probe messages per {_CHUNK_SUBROUNDS}-sub-round "
            f"chunk overflow int32 at {C} shards x {K} blocks")
    # per-request latency must stay f32-exact for integer cost models
    max_lat = _max_latency(cfg, K)
    if max_lat >= 2.0 ** 24:
        raise ValueError(
            f"per-request latency bound {max_lat:.3g} exceeds the f32 "
            f"integer-exact range (2^24); scale the cost model down")


def serve_stream(policy: str, stream,
                 cfg: ServingConfig = ServingConfig(), *,
                 telemetry: Optional[TelemetryConfig] = None):
    """Replay ``stream`` under ``policy``; returns a :class:`ServeResult`.

    ``stream`` is a :class:`~repro.core.trace.serving.RequestStream`
    (build one with :class:`~repro.core.trace.serving.ServingMix`);
    ``stream.slots`` selects batched admission — counters are
    slot-order exact for every ``B`` (see the module docstring).

    ``telemetry`` (a :class:`~repro.core.telemetry.TelemetryConfig`)
    turns on windowed observability: the return becomes a
    ``(ServeResult, repro.obs.ServeTimeline)`` pair, the result gains
    its device-side latency histogram (``lat_hist`` — percentile
    properties become exact histogram reads under the default integral
    cost model), and all counters stay bit-equal to the
    ``telemetry=None`` replay (the chunk program only *adds*
    emissions). ``None`` compiles and reuses exactly the
    pre-telemetry executables.
    """
    if policy not in SERVING_POLICIES:
        raise ValueError(f"policy must be one of {SERVING_POLICIES}, "
                         f"got {policy!r}")
    T, C, K = stream.hashes.shape
    B = stream.slots
    _check_headroom(policy, cfg, T, C, K)

    # pad the tail with invalid sub-rounds up to a whole chunk: they
    # tick the clock after the last real access (no LRU effect) and
    # carry no requests, so every counter and latency is unchanged
    pad = -T % _CHUNK_SUBROUNDS
    steps = _CHUNK_SUBROUNDS // B

    def padded(a, fill=0):
        if not pad:
            return np.asarray(a)
        return np.concatenate(
            [a, np.full((pad,) + a.shape[1:], fill, a.dtype)])

    n_chunks = (T + pad) // _CHUNK_SUBROUNDS
    shape = (n_chunks, steps, B, C)
    xs_valid = jnp.asarray(padded(stream.valid).reshape(shape))
    xs_hashes = jnp.asarray(padded(stream.hashes).reshape(shape + (K,)))
    xs_blocks = jnp.asarray(padded(stream.n_blocks).reshape(shape))

    exe = _get_executable(policy, cfg, B, C, K, steps, telemetry)
    carry = dict(
        dir=jnp.zeros((C, cfg.n_sets, cfg.n_ways, 2), jnp.int32),
        noc=init_noc_state(get_noc(cfg.noc).n_links(cfg.geometry(C))),
        t=jnp.int32(0))
    lat_parts, nl_parts, nr_parts, nc_parts = [], [], [], []
    probe_messages = 0
    shard_load = np.zeros(C, np.int64)
    with_hist = telemetry is not None and telemetry.histograms
    lat_hist = (np.zeros(serving_hist_bins(_max_latency(cfg, K)),
                         np.int64) if with_hist else None)
    pm_parts = []
    for i in range(n_chunks):
        carry, outs = exe(
            carry, (xs_valid[i], xs_hashes[i], xs_blocks[i]))
        lat_parts.append(np.asarray(outs["lat"]))
        nl_parts.append(np.asarray(outs["nl"]))
        nr_parts.append(np.asarray(outs["nr"]))
        nc_parts.append(np.asarray(outs["nc"]))
        probe_messages += int(outs["pm"])
        shard_load += np.asarray(outs["shard_load"], np.int64)
        if telemetry is not None:
            pm_parts.append(np.asarray(outs["pm_steps"], np.int64))
        if with_hist:
            lat_hist += np.asarray(outs["hist"], np.int64)

    # host-side wide reduction of the emitted per-sub-round grids
    # (int64 / float64 — the overflow-headroom accumulators)
    def grid(parts):   # (n_chunks, steps, B, C) -> (T, C), trimmed
        return np.concatenate(parts).reshape(-1, C)[:T]

    lat = grid(lat_parts)
    nl, nr, nc = grid(nl_parts), grid(nr_parts), grid(nc_parts)
    local_hits = int(nl.sum(dtype=np.int64))
    remote_hits = int(nr.sum(dtype=np.int64))
    recomputed = int(nc.sum(dtype=np.int64))
    served = np.asarray(stream.valid)
    cycles = float(np.sum(
        lat.reshape(-1, B * C).max(axis=1), dtype=np.float64))

    nt = stream.n_tenants
    tidx = np.asarray(stream.tenant)[served]

    def per_tenant(w, dtype=np.int64):
        out = np.zeros(nt, dtype)
        np.add.at(out, tidx, w[served].astype(dtype))
        return out

    ones = np.ones_like(served, np.int64)
    nstate = carry["noc"]
    result = ServeResult(
        policy=policy,
        n_requests=stream.n_requests,
        local_hits=local_hits,
        remote_hits=remote_hits,
        recomputed_blocks=recomputed,
        probe_messages=probe_messages,
        # every remote hit is exactly one remote block fetch
        remote_fetch_blocks=remote_hits,
        # ata: every sealed block rides the periodic delta all-gather
        directory_sync_entries=recomputed if policy == "ata" else 0,
        shard_load=shard_load,
        latency=lat,
        served=served,
        tenants=stream.tenants,
        tenant_requests=per_tenant(ones),
        tenant_hit_blocks=per_tenant(nl + nr),
        tenant_blocks=per_tenant(nl + nr + nc),
        tenant_latency_sum=per_tenant(lat, np.float64),
        cycles=cycles,
        slots=B,
        noc_injected=float(nstate["injected"]),
        noc_delivered=float(nstate["delivered"]),
        noc_queued=float(nstate["queue"].sum()),
        lat_hist=lat_hist,
        hist_exact=with_hist and _integral_cost_model(cfg),
    )
    if telemetry is None:
        return result
    from repro.obs.timeline import ServeTimeline  # obs sits above serving
    pm_rounds = np.concatenate(pm_parts)[:T // B]
    cycles_rounds = np.max(lat.reshape(-1, B * C), axis=1)
    timeline = ServeTimeline.from_grids(
        window=telemetry.window, slots=B, served=served,
        nl=nl, nr=nr, nc=nc, lat=lat, pm_rounds=pm_rounds,
        cycles_rounds=cycles_rounds,
        tenant=np.asarray(stream.tenant), n_tenants=nt,
        hist=lat_hist, hist_exact=result.hist_exact,
        meta={"policy": policy, "slots": B, "shards": C,
              "noc": cfg.noc, "tenants": "+".join(stream.tenants)})
    return result, timeline


def compile_count() -> int:
    """Engine executables compiled so far (CI budgets this)."""
    return len(_EXECUTABLES)
