"""Vectorized, jitted ATA serving engine.

The production-scale replacement for the Python-loop oracle
(``repro.serving.ref``): a :class:`~repro.core.trace.serving.
RequestStream` grid — one admission slot per shard per round — is
replayed by one ``lax.scan`` over rounds, so millions of requests run
in vectorized steps with no per-request Python.

Round semantics (the oracle's ``run_stream`` is the bit-exact
reference):

1. **Probe** — every arriving request compares its block chain against
   the round-start replicated directory of all shards. Under ``ata``
   this is the aggregated-tag-array compare the paper builds in
   hardware; the ``ata_tag_probe`` Pallas kernel is a selectable
   backend for it (``lax`` is the fused-XLA default, mirroring
   ``repro.core.probe.PROBE_BACKENDS``).
2. **Walk** — each request reuses its leading hits (prefix semantics);
   reuse of an own-shard block is revalidated against the *live* local
   directory (this shard's own replication inserts can evict a block
   mid-walk), remote presence is vouched for by the probe (remote
   shards never mutate each other's arrays — the local-write rule).
   Under ``ata`` a remote hit replicates into the local directory
   (paper Fig 7(a)); after the first failure all remaining blocks
   recompute and seal locally.
3. **Price** — remote fetches become :class:`~repro.core.noc.
   NocTraffic` (``flits_per_block`` flits from owner to requester)
   through a pluggable :class:`~repro.core.noc.NocModel` whose state
   carries across rounds (crossbar backpressure works); per-request
   latency folds hit/fetch/recompute terms, the broadcast policy's
   probe round trip, and the NoC delay + occupancy.

All shard updates within a round are disjoint (each shard writes only
its own directory rows), so the parallel walk is order-free; counters
are int32 in the scan carry (exact well past the f32 2^24 integer
ceiling at millions of blocks).

Policies: ``private`` (local-only), ``broadcast`` (probe all shards on
local miss — the oracle's ``remote``), ``ata`` (replicated directory,
zero probe messages). The oracle-only ``decoupled`` policy has no
engine analog (its home hash needs int64).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import GpuGeometry
from repro.core.noc import NocTraffic, get_noc, init_noc_state
from repro.kernels.ata_tag_probe import ata_tag_probe

SERVING_POLICIES = ("private", "broadcast", "ata")

#: Directory-probe backends: fused XLA gather/compare (default), the
#: ``ata_tag_probe`` Pallas kernel compiled by Mosaic (TPU), and the
#: same kernel interpreted (validation off-TPU).
SERVING_PROBE_BACKENDS = ("lax", "pallas", "pallas_interpret")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Static engine configuration (hashable: one executable per value).

    The directory mirrors :class:`~repro.serving.ref.AtaCacheConfig`
    (``n_shards`` comes from the stream). Timing terms are abstract
    serving cycles; the NoC scalars feed the
    :class:`~repro.core.geometry.GpuGeometry` the interconnect models
    price traffic with.
    """
    n_sets: int = 64
    n_ways: int = 8
    # --- latency model (cycles per block / per request) -------------
    lat_hit: float = 1.0        # local pool read per block
    lat_fetch: float = 4.0      # remote fetch base per block (+ NoC)
    lat_recompute: float = 40.0  # prefill recompute per block
    lat_probe_rtt: float = 6.0  # broadcast probe round trip per request
    # --- interconnect ----------------------------------------------
    flits_per_block: int = 4
    noc: str = "ideal"
    noc_bw: float = 16.0
    # --- probe backend ---------------------------------------------
    probe_backend: str = "lax"

    def __post_init__(self):
        if self.noc not in ("ideal", "crossbar", "ring"):
            get_noc(self.noc)   # raises with the registered list
        if self.probe_backend not in SERVING_PROBE_BACKENDS:
            raise ValueError(
                f"probe_backend must be one of {SERVING_PROBE_BACKENDS},"
                f" got {self.probe_backend!r}")

    def geometry(self, n_shards: int) -> GpuGeometry:
        """The one-cluster geometry the NoC models price traffic with."""
        return GpuGeometry(n_cores=n_shards, cluster_size=n_shards,
                           l1_sets=self.n_sets, l1_ways=self.n_ways,
                           flits_per_line=self.flits_per_block,
                           noc_bw=self.noc_bw)


class ServeResult(NamedTuple):
    """Aggregate + per-round outputs of one engine replay."""
    policy: str
    n_requests: int
    local_hits: int
    remote_hits: int
    recomputed_blocks: int
    probe_messages: int
    remote_fetch_blocks: int
    directory_sync_entries: int
    shard_load: np.ndarray          # (C,) reuse serves per shard
    latency: np.ndarray             # (T, C) f32 modeled request latency
    served: np.ndarray              # (T, C) bool request present
    tenants: Tuple[str, ...]
    tenant_requests: np.ndarray     # (n_tenants,)
    tenant_hit_blocks: np.ndarray
    tenant_blocks: np.ndarray
    tenant_latency_sum: np.ndarray  # (n_tenants,) f32
    cycles: float                   # sum of per-round critical paths
    noc_injected: float
    noc_delivered: float
    noc_queued: float

    @property
    def hit_rate(self) -> float:
        tot = self.local_hits + self.remote_hits + self.recomputed_blocks
        return (self.local_hits + self.remote_hits) / max(tot, 1)

    @property
    def request_latencies(self) -> np.ndarray:
        return self.latency[self.served]

    def latency_percentile(self, q: float) -> float:
        lat = self.request_latencies
        return float(np.percentile(lat, q)) if lat.size else 0.0

    @property
    def p50_latency(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99_latency(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def requests_per_kcycle(self) -> float:
        """Modeled throughput (requests per 1000 modeled cycles)."""
        return 1e3 * self.n_requests / max(self.cycles, 1e-9)

    @property
    def load_imbalance(self) -> float:
        m = self.shard_load.mean()
        return float(self.shard_load.max() / m) if m else 0.0


def _probe_all(tags, valid, h, set_idx, *, backend):
    """(C, K, C_dir) hits of every request block vs every directory.

    Invalid block lanes carry hash 0, which never matches (sealed tags
    are >= 1), so no masking is needed here.
    """
    C, K = h.shape
    if backend == "lax":
        g_t = tags[:, set_idx, :]                   # (C_dir, C, K, W)
        g_v = valid[:, set_idx, :]
        hits = ((g_t == h[None, :, :, None]) & g_v).any(-1)
        return jnp.transpose(hits, (1, 2, 0))       # (C, K, C_dir)
    R = C * K
    bc = 8 if C % 8 == 0 else C
    hits, _ = ata_tag_probe(
        set_idx.reshape(R), h.reshape(R), tags, valid, br=R, bc=bc,
        interpret=True if backend == "pallas_interpret" else None)
    return hits.reshape(C, K, C)


@functools.partial(jax.jit,
                   static_argnames=("policy", "cfg", "n_tenants"))
def _serve(valid_r, hashes, n_blocks, tenant, *, policy, cfg, n_tenants):
    T, C, K = hashes.shape
    S, W = cfg.n_sets, cfg.n_ways
    geom = cfg.geometry(C)
    noc = get_noc(cfg.noc)
    cidx = jnp.arange(C, dtype=jnp.int32)
    i32 = jnp.int32
    f32 = jnp.float32

    carry0 = dict(
        tags=jnp.zeros((C, S, W), i32),
        valid=jnp.zeros((C, S, W), jnp.bool_),
        last=jnp.zeros((C, S, W), i32),
        noc=init_noc_state(noc.n_links(geom)),
        local_hits=i32(0), remote_hits=i32(0),
        recomputed_blocks=i32(0), probe_messages=i32(0),
        remote_fetch_blocks=i32(0), directory_sync_entries=i32(0),
        shard_load=jnp.zeros((C,), i32),
        tenant_requests=jnp.zeros((n_tenants,), i32),
        tenant_hit_blocks=jnp.zeros((n_tenants,), i32),
        tenant_blocks=jnp.zeros((n_tenants,), i32),
        tenant_latency_sum=jnp.zeros((n_tenants,), f32),
        cycles=f32(0.0),
        t=i32(0),
    )

    def step(carry, x):
        vr, h, nb, ten = x               # (C,), (C,K), (C,), (C,)
        tags, valid, last = carry["tags"], carry["valid"], carry["last"]
        clock = carry["t"] + 1
        set_idx = (h % S).astype(i32)

        hits = _probe_all(tags, valid, h, set_idx,
                          backend=cfg.probe_backend)  # (C, K, C_dir)
        karange = jnp.arange(K)
        local_hit = hits[cidx[:, None], karange[None, :], cidx[:, None]]
        bvalid = (karange[None, :] < nb[:, None]) & vr[:, None]
        if policy == "private":
            hit = local_hit
            owner = jnp.broadcast_to(cidx[:, None], (C, K))
        else:
            hit = hits.any(-1)
            owner = jnp.where(local_hit, cidx[:, None],
                              jnp.argmax(hits, axis=-1).astype(i32))
        pm = i32(0)
        if policy == "broadcast":
            # one broadcast per locally-missing block of the chain
            pm = jnp.sum((bvalid & ~local_hit).astype(i32)) * (C - 1)

        alive = vr
        n_local = jnp.zeros((C,), i32)
        n_remote = jnp.zeros((C,), i32)
        n_recomp = jnp.zeros((C,), i32)
        shard_load = carry["shard_load"]
        block_src = []
        block_remote = []
        for k in range(K):               # static unroll over the chain
            bv = bvalid[:, k]
            hh, si = h[:, k], set_idx[:, k]
            ow = owner[:, k]
            row_t = tags[cidx, si]                       # (C, W)
            row_v = valid[cidx, si]
            row_l = last[cidx, si]
            present_way = row_v & (row_t == hh[:, None])
            present_self = present_way.any(-1)
            # own-shard reuse revalidates live; remote is probe-vouched
            ok = (ow != cidx) | present_self
            reused = alive & bv & hit[:, k] & ok
            recomp = bv & ~reused
            alive = alive & (~bv | reused)
            local = reused & (ow == cidx)
            remote = reused & ~local
            n_local += local
            n_remote += remote
            n_recomp += recomp
            shard_load = shard_load.at[jnp.where(reused, ow, C)] \
                .add(1, mode="drop")
            do_insert = (recomp | remote) if policy == "ata" else recomp
            has_free = (~row_v).any(-1)
            way = jnp.where(
                present_self, jnp.argmax(present_way, axis=-1),
                jnp.where(has_free, jnp.argmax(~row_v, axis=-1),
                          jnp.argmin(row_l, axis=-1))).astype(i32)
            row_sel = jnp.where(do_insert, cidx, C)      # OOB -> drop
            tags = tags.at[row_sel, si, way].set(hh, mode="drop")
            valid = valid.at[row_sel, si, way].set(True, mode="drop")
            last = last.at[row_sel, si, way].set(clock, mode="drop")
            block_src.append(ow)
            block_remote.append(remote)

        # --- NoC pricing: one traffic entry per remote-fetched block
        src = jnp.stack(block_src, axis=1).reshape(-1)   # (C*K,)
        rmask = jnp.stack(block_remote, axis=1).reshape(-1)
        traffic = NocTraffic(
            src=src, dst=jnp.repeat(cidx, K),
            cluster=jnp.zeros_like(src),
            flits=jnp.full((C * K,), float(cfg.flits_per_block), f32),
            mask=rmask)
        transit = noc.transit(geom, carry["noc"], traffic)
        noc_extra = (transit.delay + transit.occupancy) \
            .reshape(C, K).sum(-1)

        lat = (cfg.lat_hit * n_local + cfg.lat_fetch * n_remote
               + cfg.lat_recompute * n_recomp).astype(f32) + noc_extra
        if policy == "broadcast":
            lat += cfg.lat_probe_rtt \
                * (bvalid & ~local_hit).any(-1).astype(f32)
        lat = jnp.where(vr, lat, 0.0)

        tidx = jnp.where(vr, ten, n_tenants)             # OOB -> drop
        new = dict(
            carry,
            tags=tags, valid=valid, last=last, noc=transit.state,
            local_hits=carry["local_hits"] + n_local.sum(),
            remote_hits=carry["remote_hits"] + n_remote.sum(),
            recomputed_blocks=carry["recomputed_blocks"]
            + n_recomp.sum(),
            probe_messages=carry["probe_messages"] + pm,
            remote_fetch_blocks=carry["remote_fetch_blocks"]
            + n_remote.sum(),
            directory_sync_entries=carry["directory_sync_entries"]
            + (n_recomp.sum() if policy == "ata" else i32(0)),
            shard_load=shard_load,
            tenant_requests=carry["tenant_requests"].at[tidx]
            .add(1, mode="drop"),
            tenant_hit_blocks=carry["tenant_hit_blocks"].at[tidx]
            .add(n_local + n_remote, mode="drop"),
            tenant_blocks=carry["tenant_blocks"].at[tidx]
            .add(n_local + n_remote + n_recomp, mode="drop"),
            tenant_latency_sum=carry["tenant_latency_sum"].at[tidx]
            .add(lat, mode="drop"),
            cycles=carry["cycles"] + jnp.max(lat),
            t=clock,
        )
        return new, (lat, vr)

    final, (lat, served) = jax.lax.scan(
        step, carry0, (valid_r, hashes, n_blocks, tenant))
    return final, lat, served


def serve_stream(policy: str, stream,
                 cfg: ServingConfig = ServingConfig()) -> ServeResult:
    """Replay ``stream`` under ``policy``; returns a :class:`ServeResult`.

    ``stream`` is a :class:`~repro.core.trace.serving.RequestStream`
    (build one with :class:`~repro.core.trace.serving.ServingMix`).
    """
    if policy not in SERVING_POLICIES:
        raise ValueError(f"policy must be one of {SERVING_POLICIES}, "
                         f"got {policy!r}")
    final, lat, served = _serve(
        jnp.asarray(stream.valid), jnp.asarray(stream.hashes),
        jnp.asarray(stream.n_blocks), jnp.asarray(stream.tenant),
        policy=policy, cfg=cfg, n_tenants=stream.n_tenants)
    nstate = final["noc"]
    return ServeResult(
        policy=policy,
        n_requests=stream.n_requests,
        local_hits=int(final["local_hits"]),
        remote_hits=int(final["remote_hits"]),
        recomputed_blocks=int(final["recomputed_blocks"]),
        probe_messages=int(final["probe_messages"]),
        remote_fetch_blocks=int(final["remote_fetch_blocks"]),
        directory_sync_entries=int(final["directory_sync_entries"]),
        shard_load=np.asarray(final["shard_load"]),
        latency=np.asarray(lat),
        served=np.asarray(served),
        tenants=stream.tenants,
        tenant_requests=np.asarray(final["tenant_requests"]),
        tenant_hit_blocks=np.asarray(final["tenant_hit_blocks"]),
        tenant_blocks=np.asarray(final["tenant_blocks"]),
        tenant_latency_sum=np.asarray(final["tenant_latency_sum"]),
        cycles=float(final["cycles"]),
        noc_injected=float(nstate["injected"]),
        noc_delivered=float(nstate["delivered"]),
        noc_queued=float(nstate["queue"].sum()),
    )


def compile_count() -> int:
    """Engine executables compiled so far (CI budgets this)."""
    return int(_serve._cache_size())
