"""Reference (numpy) ATA-style distributed KV-prefix cache.

This is the original Python-loop model, retained as the **oracle** for
the vectorized engine (``repro.serving.engine``): the engine's
hit/probe/fetch accounting must match this implementation bit-exactly
on small workloads (tier-1 tested) before any scale claim counts.

The paper's mechanism mapped onto serving (DESIGN.md §3):

  GPU cores            -> serving shards (data-parallel model replicas)
  L1 data arrays       -> per-shard HBM KV-block pools
  inter-core locality  -> shared prompt prefixes across shards
  aggregated tag array -> a *replicated* block directory: every shard
                          holds the (tags, owner, slot) arrays of ALL
                          shards and probes them locally in parallel
                          (the `ata_tag_probe` kernel) — zero probe
                          messages, the paper's central trick
  request distributor  -> route each block: local pool / remote fetch
                          (only on a *known* hit) / recompute ("L2")
  local-write rule     -> new blocks are sealed into the *local* pool
                          only; directory deltas ride a tiny periodic
                          all-gather (tag-fill analog)

Baselines for the paper's Table-I landscape, same API:
  private   — per-shard pools, no remote reuse (replicated compute)
  remote    — probe broadcast to all shards on miss (probe messages +
              critical-path latency counted)
  decoupled — blocks hash-home to exactly one shard (hot-shard load
              concentration counted; no replication)
  ata       — the paper's design

Two request paths share the walk/insert machinery:

* :meth:`AtaPrefixCache.lookup_prefix` — the legacy one-request-at-a-
  time path (token arrays in, payloads out), unchanged semantics;
* :func:`run_stream` — the **round-based** reference over a
  :class:`~repro.core.trace.serving.RequestStream` grid: each round,
  all arriving requests probe the round-start directory, then apply
  their walks. The local-write rule makes per-shard updates disjoint,
  so apply order cannot matter — which is exactly what lets the
  vectorized engine replay rounds in parallel. Remote payload presence
  is vouched for by the round-start probe (the fetch snapshots remote
  data at probe resolution); only *local* presence is revalidated
  live, because a shard's own replication inserts can evict a block
  its own walk planned to reuse.

The pools/directory are modeled at block granularity with opaque
payload ids; `examples/serve_ata.py` wires it to real model KV blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

POLICIES = ("private", "remote", "decoupled", "ata")


def _home(h: int, n_shards: int) -> int:
    """Home-shard hash for decoupled policy (mixed so it does not alias
    the directory's set index, which also uses modular placement)."""
    return int((int(h) * 2654435761 >> 17) % n_shards)


@dataclasses.dataclass(frozen=True)
class AtaCacheConfig:
    n_shards: int = 8
    n_sets: int = 64          # directory sets per shard tag array
    n_ways: int = 8
    pool_slots: int = 512     # KV block slots per shard pool
    block_tokens: int = 16    # tokens per sealed block


def hash_blocks(tokens: np.ndarray, block: int) -> np.ndarray:
    """Prefix-cumulative block hashes (same prefix -> same hash chain)."""
    n = len(tokens) // block
    hashes = np.zeros(n, np.int64)
    h = np.int64(1469598103934665603)
    for i in range(n):
        for t in tokens[i * block:(i + 1) * block]:
            h = np.int64((int(h) ^ int(t)) * 1099511628211 % (1 << 63))
        hashes[i] = h
    return hashes


@dataclasses.dataclass
class Stats:
    local_hits: int = 0
    remote_hits: int = 0
    recomputed_blocks: int = 0
    probe_messages: int = 0
    remote_fetch_blocks: int = 0
    directory_sync_entries: int = 0
    shard_load: Optional[np.ndarray] = None

    @property
    def hit_rate(self) -> float:
        tot = self.local_hits + self.remote_hits + self.recomputed_blocks
        return (self.local_hits + self.remote_hits) / max(tot, 1)

    hot_block_load: int = 0

    @property
    def load_imbalance(self) -> float:
        if self.shard_load is None or self.shard_load.mean() == 0:
            return 0.0
        return float(self.shard_load.max() / self.shard_load.mean())


class AtaPrefixCache:
    """Directory + pools for one cluster of serving shards."""

    def __init__(self, cfg: AtaCacheConfig, policy: str = "ata"):
        assert policy in POLICIES
        self.cfg = cfg
        self.policy = policy
        C, S, W = cfg.n_shards, cfg.n_sets, cfg.n_ways
        self.tags = np.zeros((C, S, W), np.int64)
        self.valid = np.zeros((C, S, W), bool)
        self.slot = np.zeros((C, S, W), np.int32)
        self.last = np.zeros((C, S, W), np.int64)
        self.pool_used = np.zeros(C, np.int32)
        self.pool_payload: List[Dict[int, object]] = [
            {} for _ in range(C)]
        self.clock = 0
        self.block_load: Dict[int, int] = {}
        self.stats = Stats(shard_load=np.zeros(C, np.int64))
        # private/remote policies: each shard only *sees* its own tags
        # (remote probes peers on miss); decoupled/ata see per policy.

    # -- directory primitives ------------------------------------------------
    def _set_idx(self, h: np.ndarray) -> np.ndarray:
        return (h % self.cfg.n_sets).astype(np.int64)

    def probe(self, shard: int, hashes: np.ndarray,
              scope: str) -> Tuple[np.ndarray, np.ndarray]:
        """(hit, owner) for each hash. scope: 'local'|'all'|'home'."""
        C = self.cfg.n_shards
        sets = self._set_idx(hashes)
        hit = np.zeros(len(hashes), bool)
        owner = np.full(len(hashes), -1, np.int32)
        shards = {"local": [shard], "all": list(range(C)),
                  "home": [_home(h, C) for h in hashes]}[scope]
        for i, h in enumerate(hashes):
            cand = (shards if scope != "home" else [shards[i]])
            for c in cand:
                m = self.valid[c, sets[i]] & (self.tags[c, sets[i]] == h)
                if m.any():
                    hit[i] = True
                    if owner[i] < 0 or c == shard:
                        owner[i] = c   # paper: prefer the local cache
        return hit, owner

    def insert(self, shard: int, h: int, payload: object):
        s = int(self._set_idx(np.array([h]))[0])
        present = np.where(self.valid[shard, s]
                           & (self.tags[shard, s] == h))[0]
        if len(present):                       # already cached: touch LRU
            self.last[shard, s, int(present[0])] = self.clock
            self.pool_payload[shard][h] = payload
            return
        free = np.where(~self.valid[shard, s])[0]
        w = int(free[0]) if len(free) else int(
            np.argmin(self.last[shard, s]))
        evicted = self.tags[shard, s, w]
        if self.valid[shard, s, w]:
            self.pool_payload[shard].pop(int(evicted), None)
        self.tags[shard, s, w] = h
        self.valid[shard, s, w] = True
        self.last[shard, s, w] = self.clock
        self.pool_payload[shard][h] = payload
        self.pool_used[shard] += 1

    # -- request path ---------------------------------------------------------
    def probe_blocks(self, shard: int, hashes: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-policy probe for one request's block chain -> (hit, owner).

        The ``remote`` policy's probe-message accounting happens here
        (at probe time, one broadcast per locally-missing block),
        exactly as in the pre-split ``lookup_prefix``.
        """
        if self.policy == "private":
            return self.probe(shard, hashes, "local")
        if self.policy == "decoupled":
            return self.probe(shard, hashes, "home")
        if self.policy == "remote":
            lhit, _ = self.probe(shard, hashes, "local")
            self.stats.probe_messages += int((~lhit).sum()) \
                * (self.cfg.n_shards - 1)
            return self.probe(shard, hashes, "all")
        # ata: replicated directory, local parallel compare
        return self.probe(shard, hashes, "all")

    def apply_blocks(self, shard: int, hashes: np.ndarray,
                     hit: np.ndarray, owner: np.ndarray
                     ) -> Tuple[int, List[object]]:
        """Walk one request's chain against a prior probe result.

        Reuses leading hits (prefix semantics: the first failure stops
        reuse), then recomputes + seals the rest per the policy's
        write rule. Remote presence is vouched for by the probe (the
        fetch snapshots the remote pool at probe resolution; remote
        shards only ever mutate their *own* arrays, so within a
        sequential lookup this is identical to the historical live
        check). Local presence is revalidated live — this shard's own
        replication inserts may have evicted a block the probe saw.
        """
        st = self.stats
        payloads: List[object] = []
        reused = 0
        for i, h in enumerate(hashes):
            if not hit[i]:
                break
            src = int(owner[i])
            payload = self.pool_payload[src].get(int(h))
            if src == shard and payload is None:
                break
            if payload is None:                 # remote: probe vouches
                payload = ("blk", int(h))
            payloads.append(payload)
            reused += 1
            st.shard_load[src] += 1
            self.block_load[int(h)] = self.block_load.get(int(h), 0) + 1
            if src == shard:
                st.local_hits += 1
            else:
                st.remote_hits += 1
                st.remote_fetch_blocks += 1
                if self.policy == "ata":
                    # paper Fig 7(a): remote fetch also fills the local
                    # cache -> hot blocks replicate and load spreads
                    self.insert(shard, int(h), payload)

        # recompute the rest; seal new blocks per policy's write rule
        for i in range(reused, len(hashes)):
            st.recomputed_blocks += 1
            home = (_home(hashes[i], self.cfg.n_shards)
                    if self.policy == "decoupled" else shard)
            if self.policy == "ata":
                st.directory_sync_entries += 1   # delta all-gather entry
            self.insert(home, int(hashes[i]), ("blk", int(hashes[i])))
        return reused, payloads

    def lookup_prefix(self, shard: int, tokens: np.ndarray
                      ) -> Tuple[int, List[object]]:
        """Longest reusable prefix for a request arriving at `shard`.

        Returns (#reused blocks, payloads). Misses past the first gap
        stop reuse (prefix semantics). Updates stats per policy.
        """
        self.clock += 1
        hashes = hash_blocks(tokens, self.cfg.block_tokens)
        hit, owner = self.probe_blocks(shard, hashes)
        return self.apply_blocks(shard, hashes, hit, owner)


def run_workload(policy: str, cfg: AtaCacheConfig, requests,
                 ) -> Stats:
    """requests: iterable of (shard, token-array)."""
    cache = AtaPrefixCache(cfg, policy)
    for shard, toks in requests:
        cache.lookup_prefix(int(shard), np.asarray(toks))
    if cache.block_load:
        cache.stats.hot_block_load = max(cache.block_load.values())
    return cache.stats


def run_stream(policy: str, cfg: AtaCacheConfig, stream) -> Stats:
    """Round-based oracle over a ``RequestStream`` grid.

    The reference semantics the vectorized engine must reproduce
    bit-exactly: each round, every arriving request probes the
    round-start directory (all probes before any apply); then every
    request applies its walk. The local-write rule makes the applies
    disjoint per shard, so their order is irrelevant. The clock ticks
    once per *round* (LRU timestamps are round-granular).

    ``policy`` accepts the engine's name ``"broadcast"`` as an alias
    for the legacy ``"remote"``; ``"decoupled"`` stays a
    ``lookup_prefix``-only policy (its int64 home hash has no int32
    engine analog).

    **Batched admission** (``stream.slots = B > 1``) needs no code
    here — and that is the point of the slot-major layout: the
    engine's batched contract is "replay the ``B`` slots of a round as
    sequential sub-rounds", and this loop's row order *is* that
    sequential replay (one clock tick per row = one per sub-round).
    The oracle therefore sequentializes slots by construction, and its
    counters are the reference for every ``B`` at once; the
    exactness tests also route through
    ``stream.slot_sequential()`` to make the comparison explicit.
    """
    stream = stream.slot_sequential()
    policy = {"broadcast": "remote"}.get(policy, policy)
    if policy not in ("private", "remote", "ata"):
        raise ValueError(f"run_stream supports private/broadcast/ata, "
                         f"got {policy!r}")
    cfg = dataclasses.replace(cfg, n_shards=stream.n_shards)
    cache = AtaPrefixCache(cfg, policy)
    T, C = stream.rounds, stream.n_shards
    for t in range(T):
        cache.clock += 1
        probes = []
        for c in range(C):
            if not stream.valid[t, c]:
                continue
            hashes = stream.hashes[t, c, :int(stream.n_blocks[t, c])] \
                .astype(np.int64)
            probes.append((c, hashes) + cache.probe_blocks(c, hashes))
        for c, hashes, hit, owner in probes:
            cache.apply_blocks(c, hashes, hit, owner)
    if cache.block_load:
        cache.stats.hot_block_load = max(cache.block_load.values())
    return cache.stats


def synth_requests(n: int, *, n_shards: int, vocab: int = 1000,
                   n_prefixes: int = 12, prefix_blocks: int = 8,
                   unique_blocks: int = 4, block: int = 16,
                   shared_frac: float = 0.7, seed: int = 0):
    """Prompt workload with shared system-prompt prefixes (inter-core
    locality analog): shared_frac of requests start from one of
    n_prefixes common prefixes."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, prefix_blocks * block)
                for _ in range(n_prefixes)]
    out = []
    for i in range(n):
        shard = rng.integers(0, n_shards)
        uniq = rng.integers(0, vocab, unique_blocks * block)
        if rng.random() < shared_frac:
            p = prefixes[rng.integers(0, n_prefixes)]
            toks = np.concatenate([p, uniq])
        else:
            toks = np.concatenate(
                [rng.integers(0, vocab, prefix_blocks * block), uniq])
        out.append((shard, toks))
    return out
