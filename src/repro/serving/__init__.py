"""ATA-style serving: numpy oracle (ref) + vectorized engine (engine).

``ref`` keeps the original one-request-at-a-time API (the oracle the
engine is tested against bit-exactly); ``engine`` replays
:class:`~repro.core.trace.serving.RequestStream` grids under
``lax.scan`` at production request counts.
"""
from repro.serving.ref import (AtaCacheConfig, AtaPrefixCache, POLICIES,
                               Stats, hash_blocks, run_stream,
                               run_workload, synth_requests)
from repro.serving.engine import (SERVING_POLICIES,
                                  SERVING_PROBE_BACKENDS, ServeResult,
                                  ServingConfig, serve_stream)

__all__ = [
    "AtaCacheConfig", "AtaPrefixCache", "POLICIES", "Stats",
    "hash_blocks", "run_stream", "run_workload", "synth_requests",
    "SERVING_POLICIES", "SERVING_PROBE_BACKENDS", "ServeResult",
    "ServingConfig", "serve_stream",
]
