from repro.serving.ata_cache import (AtaCacheConfig, AtaPrefixCache,
                                     POLICIES, Stats, hash_blocks,
                                     run_workload, synth_requests)
