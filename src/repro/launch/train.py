"""End-to-end training driver (CPU-runnable; production mesh on TPU).

Wires the full substrate: config -> mesh/rules -> sharded train_step ->
deterministic data pipeline -> async checkpointing -> fault-tolerant
resume -> straggler watchdog. `examples/train_lm.py` drives a ~100M
model for a few hundred steps with this entry point.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --smoke --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, make_batch
from repro.launch import specs as SP
from repro.launch.mesh import make_test_mesh
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import make_rules, param_shardings, rules_context
from repro.train.step import init_train_state, make_train_step


@dataclasses.dataclass
class Watchdog:
    """Straggler/hang mitigation: flags steps slower than k x median.

    On real pods this feeds the controller that re-slices the job
    (elastic re-mesh via CheckpointStore.restore onto a new mesh); here
    it logs and counts.
    """
    factor: float = 3.0
    history: Optional[list] = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.history = (self.history or [])
        self.history.append(dt)
        med = float(np.median(self.history[-50:]))
        slow = len(self.history) > 5 and dt > self.factor * med
        self.flagged += int(slow)
        return slow


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          mesh=None, opt: Optional[AdamWConfig] = None,
          log_every: int = 10, resume: bool = True):
    opt = opt or AdamWConfig(total_steps=steps)
    rules = make_rules(cfg, mesh, batch_size=global_batch) if mesh else None
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch)
    store = CheckpointStore(ckpt_dir) if ckpt_dir else None
    watchdog = Watchdog()

    ctx = rules_context(mesh, rules) if mesh else None
    if ctx:
        ctx.__enter__()
    try:
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        start = 0
        if store and resume and store.latest_step() is not None:
            sh = None
            if mesh:
                sh = {"params": param_shardings(state["params"], mesh, rules)}
            state, start = store.restore(state)
            print(f"[train] resumed from step {start}")
        step_fn = make_train_step(cfg, opt)
        if mesh:
            st_sh = SP.train_state_shardings(
                jax.eval_shape(lambda: state), cfg, mesh, rules)
            state = jax.device_put(state, st_sh)
            step_fn = jax.jit(step_fn, in_shardings=(st_sh, None),
                              out_shardings=(st_sh, None), donate_argnums=0)
        else:
            step_fn = jax.jit(step_fn, donate_argnums=0)

        losses = []
        for step in range(start, steps):
            t0 = time.time()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in make_batch(dcfg, step).items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if watchdog.observe(dt):
                print(f"[train] straggler: step {step} took {dt:.2f}s")
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt:.2f}s)", flush=True)
            if store and (step + 1) % ckpt_every == 0:
                store.save(step + 1, state)
        if store:
            store.save(steps, state, wait=True)
        return state, losses
    finally:
        if ctx:
            ctx.__exit__(None, None, None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default="none",
                    help="none | test (2x2 host devices)")
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_test_mesh() if args.mesh == "test" else None
    train(cfg, steps=args.steps, global_batch=args.batch,
          seq_len=args.seq, ckpt_dir=args.ckpt_dir, mesh=mesh)


if __name__ == "__main__":
    main()
