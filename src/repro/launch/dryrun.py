import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import: jax locks the
# device count at first initialization.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step for train
shapes, serve_step/prefill for inference shapes) with production
shardings, compiles it (SPMD, 256 or 512 partitions), and records:

  memory_analysis()      - bytes per device (proves it fits)
  cost_analysis()        - XLA's flop/byte counts (scan body once)
  hlo_analysis           - honest whole-program dot FLOPs + collective
                           bytes with while-trip multipliers
  roofline terms         - compute / memory / collective seconds on
                           TPU v5e constants, + MODEL_FLOPS = 6ND

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
Results land in results/dryrun/<cell>.json (one process per cell is
recommended; see scripts/run_dryrun_all.py).
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.launch import specs as SP
from repro.launch.hlo_analysis import analyze_text
from repro.launch.mesh import make_production_mesh
from repro.sharding.compat import activate_mesh
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import make_rules, rules_context
from repro.train.step import make_train_step

# --- TPU v5e constants ------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, per direction)

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode: D = batch tokens."""
    n = cfg.active_param_count()
    _, dec_len = SP.split_lens(cfg, shape.seq_len)
    if shape.kind == "train":
        tokens = shape.global_batch * dec_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * dec_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # one token per sequence


def build_cell(arch: str, shape_name: str, multi_pod: bool, *,
               profile: str = "tp", accum: int = 1,
               donate_cache: bool = False, kv_dtype: str = None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = SP.tune_for_mesh(cfg, mesh)
    if kv_dtype:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, kv_cache_dtype=kv_dtype)
    rules = make_rules(cfg, mesh, batch_size=shape.global_batch,
                       profile=profile)
    t0 = time.time()

    with rules_context(mesh, rules), activate_mesh(mesh):
        if shape.kind == "train":
            state_shape = SP.abstract_train_state(cfg)
            st_sh = SP.train_state_shardings(state_shape, cfg, mesh, rules)
            batch = SP.input_specs(cfg, shape)
            b_sh = SP.batch_shardings(batch, mesh, rules)
            opt_cfg = AdamWConfig()
            step = make_train_step(cfg, opt_cfg, accum_steps=accum)
            fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, None))
            lowered = fn.lower(state_shape, batch)
        elif shape.kind == "prefill":
            params_shape = SP.abstract_params(cfg)
            from repro.sharding.rules import param_shardings
            psh = param_shardings(params_shape, mesh, rules)
            batch = SP.input_specs(cfg, shape)
            b_sh = SP.batch_shardings(batch, mesh, rules)

            def prefill(params, b):
                logits, _ = T.forward(params, cfg, b["tokens"],
                                      enc_frames=b.get("enc_frames"))
                return logits

            fn = jax.jit(prefill, in_shardings=(psh, b_sh),
                         out_shardings=None)
            lowered = fn.lower(params_shape, batch)
        else:  # decode
            params_shape = SP.abstract_params(cfg)
            from repro.sharding.rules import param_shardings
            psh = param_shardings(params_shape, mesh, rules)
            inputs = SP.input_specs(cfg, shape, abstract_params=params_shape)
            c_sh = SP.cache_shardings(inputs["cache"], mesh, rules)
            from repro.sharding.rules import logical_to_spec
            tok_spec = logical_to_spec(("batch", None), rules)
            tok_sh = NamedSharding(mesh, tok_spec)

            def serve_step(params, tokens, cache):
                return T.decode_step(params, cfg, tokens, cache)

            fn = jax.jit(serve_step,
                         in_shardings=(psh, tok_sh, c_sh),
                         out_shardings=(None, c_sh),
                         donate_argnums=(2,) if donate_cache else ())
            lowered = fn.lower(params_shape,
                               jax.ShapeDtypeStruct((shape.global_batch, 1),
                                                    jnp.int32),
                               inputs["cache"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    hlo = analyze_text(hlo_text)

    chips = mesh.size
    mf = model_flops(cfg, shape)
    flops_dev = hlo["dot_flops"]                   # per-device program
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = hlo["dot_bytes"] / HBM_BW
    coll_s = hlo["collective_total"] / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", coll_s)), key=lambda kv: kv[1])[0]

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "chips": chips,
        "kv_repeat": cfg.kv_repeat,
        "variant": {"profile": profile, "accum": accum,
                    "donate_cache": donate_cache, "kv_dtype": kv_dtype},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_estimate_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "xla_cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")
                     if k in cost},
        "hlo": {
            "dot_flops_per_device": flops_dev,
            "dot_bytes_per_device": hlo["dot_bytes"],
            "collective_bytes_per_device": hlo["collective_bytes"],
            "collective_total_per_device": hlo["collective_total"],
        },
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": dominant,
            "model_flops_global": mf,
            "useful_flops_ratio": (mf / (flops_dev * chips)
                                   if flops_dev else None),
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--profile", default="tp")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--donate-cache", action="store_true")
    ap.add_argument("--kv-dtype", default=None)
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            tag = f"{arch}__{shape}__{'mp' if args.multi_pod else 'sp'}"
            out = pathlib.Path(args.out) if args.out \
                else RESULTS_DIR / f"{tag}.json"
            try:
                res = build_cell(arch, shape, args.multi_pod,
                                 profile=args.profile, accum=args.accum,
                                 donate_cache=args.donate_cache,
                                 kv_dtype=args.kv_dtype)
            except Exception as e:          # noqa: BLE001
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
            out.write_text(json.dumps(res, indent=1, default=str))
            if res.get("status") == "ok" and "hlo_text" in dir():
                pass
            status = res["status"]
            extra = ""
            if status == "ok":
                r = res["roofline"]
                extra = (f" dominant={r['dominant']} "
                         f"compute={r['compute_s']:.3f}s "
                         f"mem={r['memory_s']:.3f}s "
                         f"coll={r['collective_s']:.3f}s "
                         f"peak/dev={res['memory']['peak_estimate_gb']}GB")
            elif status == "error":
                extra = " " + res["error"][:200]
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
