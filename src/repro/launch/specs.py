"""Abstract input specs + shardings for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
no allocation); ``*_shardings`` map them (and the train/serve state
pytrees) onto the mesh through the per-arch logical rules.
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.sharding.rules import Rules, logical_to_spec, make_rules


def tune_for_mesh(cfg: ModelConfig, mesh: Mesh) -> ModelConfig:
    """Set kv_repeat so expanded KV heads divide the model axis."""
    import dataclasses
    msize = mesh.shape.get("model", 1)
    if msize > 1 and cfg.n_heads % msize == 0:
        r = math.lcm(cfg.n_kv_heads, msize) // cfg.n_kv_heads
        if r * cfg.n_kv_heads <= cfg.n_heads:
            return dataclasses.replace(cfg, kv_repeat=r)
    return cfg


def split_lens(cfg: ModelConfig, seq_len: int) -> Tuple[int, int]:
    """(encoder_len, decoder_len): enc-dec archs split the token budget."""
    if cfg.is_enc_dec:
        return seq_len // 2, seq_len // 2
    return 0, seq_len


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                abstract_params=None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function's data inputs."""
    B = shape.global_batch
    enc_len, dec_len = split_lens(cfg, shape.seq_len)
    i32 = jnp.int32
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((B, dec_len), i32),
               "labels": jax.ShapeDtypeStruct((B, dec_len), i32)}
        if cfg.is_enc_dec:
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (B, enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, dec_len), i32)}
        if cfg.is_enc_dec:
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (B, enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
        return out
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda p: T.init_cache(cfg, B, dec_len, start_len=dec_len - 1,
                                   params=p,
                                   **({"enc_frames": jnp.zeros(
                                       (B, enc_len, cfg.d_model),
                                       jnp.dtype(cfg.dtype))}
                                      if cfg.is_enc_dec else {})),
            abstract_params)
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "cache": cache}
    raise ValueError(shape.kind)


#: cache-leaf path -> logical axes (leading stacked dim handled in code)
_CACHE_PATTERNS = (
    (r".*/(k|v|k_scale|v_scale)$",
     ("batch", "cache_kv_heads", "cache_seq", "head_dim")),
    (r".*/len$", ("batch",)),
    (r".*/wkv$", ("batch", "rheads", "rkey", "rvalue")),
    (r".*/shift$", ("batch", "embed")),
    (r".*/conv$", ("batch", None, "rnn")),
    (r".*/h$", ("batch", "rnn")),
)


def _tree_pspecs(tree, patterns, rules: Rules):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat[0]:
        spath = "/".join(p.key if hasattr(p, "key") else str(p.idx)
                         for p in path)
        for pat, axes in patterns:
            if re.match(pat, spath):
                if len(axes) + 1 == leaf.ndim:
                    axes = (None,) + axes
                elif len(axes) != leaf.ndim:
                    raise ValueError(f"{spath}: rank {leaf.ndim} vs {axes}")
                out.append(logical_to_spec(axes, rules))
                break
        else:
            raise ValueError(f"no cache axis rule for {spath}")
    return jax.tree_util.tree_unflatten(flat[1], out)


def cache_shardings(cache_tree, mesh: Mesh, rules: Rules):
    specs = _tree_pspecs(cache_tree, _CACHE_PATTERNS, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def batch_shardings(batch_tree, mesh: Mesh, rules: Rules):
    spec = logical_to_spec(("batch",), rules)
    def shard(leaf):
        extra = (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*(tuple(spec) + extra)))
    return jax.tree.map(shard, batch_tree)


def abstract_train_state(cfg: ModelConfig):
    from repro.train.step import init_train_state
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg))


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def train_state_shardings(state_shape, cfg: ModelConfig, mesh: Mesh,
                          rules: Rules):
    from repro.sharding.rules import param_shardings
    from repro.optim.adamw import zero1_shardings
    psh = param_shardings(state_shape["params"], mesh, rules)
    osh = zero1_shardings(psh, mesh, state_shape["params"])
    return {"params": psh, "opt": osh}
