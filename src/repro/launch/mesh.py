"""Production mesh construction (TPU v5e pods; host-device dry-run)."""
from __future__ import annotations

from repro.sharding.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one 256-chip v5e pod) or 2x16x16 (two pods over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU tests (requires host device count >= product)."""
    return make_mesh((n_data, n_model), ("data", "model"))
