"""Post-optimization HLO text analysis for the roofline terms.

XLA's ``cost_analysis()`` counts a ``while`` body once, so scanned-layer
models under-report by ~n_layers. This parser rebuilds honest whole-
program counts from the compiled HLO text:

  * builds the computation call graph (while bodies via
    ``backend_config known_trip_count``, fusions/calls via ``calls=``),
  * assigns every computation a trip multiplier,
  * sums dot FLOPs (2 * prod(out) * prod(contracted lhs dims)) and
    collective payload bytes (per-device shard shapes, since SPMD HLO is
    the per-device program) with those multipliers.

Collective byte conventions (ring algorithms, per device):
  all-reduce 2x input, all-gather 1x output, reduce-scatter 1x input,
  all-to-all 1x input, collective-permute 1x input.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_TYPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|s32|u32|s64|u64|f8e4m3fn|f8e5m2|f16|bf16|f32|f64"
    r"|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class HloProgram:
    def __init__(self, text: str):
        self.ops: Dict[str, dict] = {}
        self.comp_of: Dict[str, str] = {}
        self.comps: List[str] = []
        self._parse(text)
        self.mult = self._multipliers()

    def _parse(self, text: str):
        comp = None
        for line in text.splitlines():
            stripped = line.strip()
            # computation headers: "%name (params) -> type {" / "ENTRY ..."
            if (stripped.endswith("{") and "->" in stripped
                    and " = " not in stripped.split("->")[0]):
                mc = _COMP_RE.match(stripped)
                if mc:
                    comp = mc.group(1)
                    self.comps.append(comp)
                    continue
            mo = _OP_RE.match(line)
            if mo and comp is not None:
                name, out_type, opcode = mo.groups()
                self.ops[name] = {
                    "type": out_type, "opcode": opcode,
                    "line": line, "comp": comp,
                }
                self.comp_of[name] = comp

    def _multipliers(self) -> Dict[str, float]:
        # edges comp -> (callee, factor)
        edges = defaultdict(list)
        for name, op in self.ops.items():
            line = op["line"]
            if op["opcode"] == "while":
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mt = re.search(r'known_trip_count[^0-9]*(\d+)', line)
                trip = int(mt.group(1)) if mt else 1
                if mb:
                    edges[op["comp"]].append((mb.group(1), trip))
                mcond = re.search(r"condition=%?([\w.\-]+)", line)
                if mcond:
                    edges[op["comp"]].append((mcond.group(1), trip))
            else:
                for callee in re.findall(r"calls=%?([\w.\-]+)", line):
                    edges[op["comp"]].append((callee, 1))
                mto = re.search(r"to_apply=%?([\w.\-]+)", line)
                if mto:
                    edges[op["comp"]].append((mto.group(1), 1))

        mult: Dict[str, float] = defaultdict(float)
        entry = self.comps[-1] if self.comps else None
        # ENTRY is the computation not called by anyone
        called = {c for lst in edges.values() for c, _ in lst}
        roots = [c for c in self.comps if c not in called] or [entry]
        for r in roots:
            mult[r] = 1.0
        # propagate (call graph is a DAG; iterate to fixed point)
        for _ in range(64):
            changed = False
            for parent, lst in edges.items():
                if mult[parent] <= 0:
                    continue
                for callee, factor in lst:
                    want = mult[parent] * factor
                    if mult[callee] < want:
                        mult[callee] = want
                        changed = True
            if not changed:
                break
        return dict(mult)

    # -- effective-dtype resolution ------------------------------------------
    def _source_type(self, name: str, depth: int = 4) -> str:
        """Follow converts / convert-wrapper fusions / copies to the
        source tensor's type: XLA-CPU upcasts every bf16 dot to f32 via
        convert pairs, and int8 KV caches are dequantized before use —
        counting the *source* dtype gives TPU-faithful byte counts."""
        op = self.ops.get(name)
        if op is None or depth == 0:
            return ""
        opc = op["opcode"]
        passthrough = opc in ("convert", "copy", "bitcast", "transpose",
                              "reshape", "broadcast")
        if opc == "fusion" and ("convert" in name or "copy" in name):
            passthrough = True
        if passthrough:
            m = re.search(rf"{opc}\(([^)]*)\)", op["line"])
            if m:
                first = m.group(1).split(",")[0].strip().lstrip("%")
                src = self._source_type(first, depth - 1)
                if src:
                    return src
        return op["type"]

    def _operand_bytes(self, arg: str) -> int:
        src = self.ops.get(arg)
        if src is None:
            return 0
        t = self._source_type(arg)
        own = _shape_dims(src["type"])
        src_dims = _shape_dims(t)
        # same element count -> use source dtype; else keep own type
        n_own = 1
        for d in own:
            n_own *= d
        n_src = 1
        for d in src_dims:
            n_src *= d
        if n_own == n_src and t:
            per = _shape_bytes(t) / max(n_src, 1)
            return int(n_own * per)
        return _shape_bytes(src["type"])

    # -- public ------------------------------------------------------------
    def dot_flops(self) -> float:
        total = 0.0
        for name, op in self.ops.items():
            if op["opcode"] != "dot":
                continue
            line = op["line"]
            out_dims = _shape_dims(op["type"])
            margs = re.search(r"dot\(([^)]*)\)", line)
            if not margs:
                continue
            args = [a.strip().lstrip("%") for a in margs.group(1).split(",")]
            lhs = self.ops.get(args[0])
            if lhs is None:
                continue
            lhs_dims = _shape_dims(lhs["type"])
            mcd = re.search(r"lhs_contracting_dims={([0-9,]*)}", line)
            contract = 1
            if mcd and mcd.group(1):
                for d in mcd.group(1).split(","):
                    contract *= lhs_dims[int(d)]
            out_n = 1
            for d in out_dims:
                out_n *= d
            total += 2.0 * out_n * contract \
                * self.mult.get(op["comp"], 1.0)
        return total

    def dot_bytes(self) -> float:
        """Operand+output bytes over dot ops (DRAM-traffic proxy),
        operand dtypes resolved through converts (see above)."""
        total = 0.0
        for name, op in self.ops.items():
            if op["opcode"] != "dot":
                continue
            m = self.mult.get(op["comp"], 1.0)
            margs = re.search(r"dot\(([^)]*)\)", op["line"])
            opb = raw = 0
            if margs:
                for a in margs.group(1).split(","):
                    a = a.strip().lstrip("%")
                    opb += self._operand_bytes(a)
                    if a in self.ops:
                        raw += _shape_bytes(self.ops[a]["type"])
            outb = _shape_bytes(op["type"])
            if opb and opb < raw and "f32[" in op["type"]:
                outb //= 2   # upcast operands: TPU writes the narrow type
            total += (opb + outb) * m
        return total

    def collective_bytes(self) -> Dict[str, float]:
        out: Dict[str, float] = defaultdict(float)
        for name, op in self.ops.items():
            kind = op["opcode"].replace("-start", "")
            if kind not in COLLECTIVES:
                continue
            m = self.mult.get(op["comp"], 1.0)
            out_b = _shape_bytes(op["type"])
            in_b = in_raw = 0
            margs = re.search(rf"{op['opcode']}\(([^)]*)\)", op["line"])
            if margs:
                for a in margs.group(1).split(","):
                    a = a.strip().lstrip("%")
                    in_b += self._operand_bytes(a)
                    if a in self.ops:
                        in_raw += _shape_bytes(self.ops[a]["type"])
            if in_b and in_b < in_raw:
                # operands were CPU-upcast f32: the TPU wire payload is
                # the narrow source type on the output side too
                out_b = int(out_b * in_b / max(in_raw, 1))
            if kind == "all-reduce":
                bytes_ = 2 * in_b
            elif kind == "all-gather":
                bytes_ = out_b
            else:
                bytes_ = in_b if in_b else out_b
            out[kind] += bytes_ * m
        return dict(out)

    def summary(self) -> dict:
        coll = self.collective_bytes()
        return {
            "dot_flops": self.dot_flops(),
            "dot_bytes": self.dot_bytes(),
            "collective_bytes": coll,
            "collective_total": sum(coll.values()),
            "n_computations": len(self.comps),
            "n_ops": len(self.ops),
        }


def analyze_text(text: str) -> dict:
    return HloProgram(text).summary()
