"""Batched serving driver with the ATA prefix cache.

Per request batch: probe the replicated ATA block directory for the
longest shared-prefix reuse (zero probe traffic), prefill only the
uncached suffix, seal new KV blocks into the *local* shard's pool, and
run batched decode steps. `examples/serve_ata.py` exercises this with a
smoke model + measurable prefix-reuse savings vs the baselines.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 32 --decode-steps 16 --policy ata
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T
from repro.serving.ref import (AtaCacheConfig, AtaPrefixCache,
                               hash_blocks, synth_requests)


class ModelServer:
    """One logical serving shard holding real model KV block payloads."""

    def __init__(self, cfg, params, ata: AtaPrefixCache, shard: int,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.ata = ata
        self.shard = shard
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, t: T.forward(p, cfg, t))
        self._decode = jax.jit(
            lambda p, t, c: T.decode_step(p, cfg, t, c))

    def _prefill_cache(self, tokens: np.ndarray) -> Dict:
        """Build a decode cache by teacher-forcing tokens one at a time
        (exercises decode path; payloads become reusable blocks)."""
        B = 1
        cache = T.init_cache(self.cfg, B, self.max_len)
        for t in tokens:
            _, cache = self._decode(self.params,
                                    jnp.asarray([[t]], jnp.int32), cache)
        return cache

    def serve(self, tokens: np.ndarray, decode_steps: int
              ) -> Tuple[List[int], Dict[str, float]]:
        t0 = time.time()
        block = self.ata.cfg.block_tokens
        n_blocks = len(tokens) // block
        reused, payloads = self.ata.lookup_prefix(self.shard, tokens)
        # payloads hold (cache pytree snapshot) at each block boundary;
        # resume from the deepest one and recompute only the suffix.
        if reused and isinstance(payloads[-1], dict):
            cache = jax.tree.map(jnp.copy, payloads[-1])
            suffix = tokens[reused * block:]
        else:
            cache = T.init_cache(self.cfg, 1, self.max_len)
            suffix = tokens
            reused = 0
        for i, t in enumerate(suffix):
            _, cache = self._decode(self.params,
                                    jnp.asarray([[t]], jnp.int32), cache)
            # seal a block snapshot at block boundaries (local write rule)
            pos = reused * block + i + 1
            if pos % block == 0:
                h = int(hash_blocks(tokens[:pos], block)[-1])
                self.ata.pool_payload[self.shard][h] = jax.tree.map(
                    jnp.copy, cache)
        out = []
        last = jnp.asarray([[int(tokens[-1])]], jnp.int32)
        for _ in range(decode_steps):
            logits, cache = self._decode(self.params, last, cache)
            nxt = int(jnp.argmax(logits[0, -1]))
            out.append(nxt)
            last = jnp.asarray([[nxt]], jnp.int32)
        return out, {"reused_blocks": reused,
                     "prefill_tokens": len(suffix),
                     "latency_s": time.time() - t0}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--policy", default="ata")
    ap.add_argument("--shards", type=int, default=4)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    acfg = AtaCacheConfig(n_shards=args.shards, block_tokens=16)
    ata = AtaPrefixCache(acfg, args.policy)
    servers = [ModelServer(cfg, params, ata, s) for s in range(args.shards)]
    reqs = synth_requests(args.requests, n_shards=args.shards,
                          vocab=cfg.vocab_size, shared_frac=0.7)
    tot_prefill = 0
    tot_reused = 0
    for shard, toks in reqs:
        _, m = servers[int(shard)].serve(np.asarray(toks),
                                         args.decode_steps)
        tot_prefill += m["prefill_tokens"]
        tot_reused += m["reused_blocks"] * acfg.block_tokens
    st = ata.stats
    print(f"[serve:{args.policy}] requests={args.requests} "
          f"prefill_tokens={tot_prefill} reused_tokens={tot_reused} "
          f"hit_rate={st.hit_rate:.3f} local={st.local_hits} "
          f"remote={st.remote_hits} probes={st.probe_messages}")


if __name__ == "__main__":
    main()
