"""Blocked (flash) attention Pallas kernel for TPU.

Online-softmax attention tiled for VMEM: grid (B, Hq, Tq/bq, Tk/bk) with
the KV axis innermost; scratch accumulators (acc, m, l) persist across
the KV sweep (TPU grids execute sequentially). Supports:

  - GQA: Hq a multiple of Hkv; the K/V BlockSpec index map folds the
    query head onto its KV head, so KV tiles are fetched once per group.
  - causal masking with end-aligned positions (prefill and decode),
  - sliding local window (RecurrentGemma-style local attention),
  - per-batch KV valid length (decode against a partially filled cache).

Block shapes are (bq, D)/(bk, D) with D = head_dim; bq/bk default 128 to
align the MXU contraction dims. Fully-masked KV tiles are skipped with
``pl.when`` (no FLOPs, no NaN-generating -inf rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, *, scale, causal, window,
                 bq, bk, tq, tk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = kvlen_ref[0]
    q_start = iq * bq + (tk - tq)          # end-aligned global positions
    k_start = ik * bk

    # ---- block-level visibility (skip fully masked KV tiles) -------------
    visible = k_start < kv_len
    if causal:
        visible &= k_start <= q_start + bq - 1
    if window is not None:
        visible &= k_start + bk - 1 > q_start - window

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, kv_len=None, *, causal: bool = True,
                    window: int | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q (B,Hq,Tq,D), k/v (B,Hkv,Tk,D), kv_len (B,) -> (B,Hq,Tq,D)."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    if Hq % Hkv:
        raise ValueError("Hq must be a multiple of Hkv")
    group = Hq // Hkv
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    if Tq % bq or Tk % bk:
        raise ValueError(f"Tq={Tq}/Tk={Tk} must tile by ({bq},{bk})")
    if kv_len is None:
        kv_len = jnp.full((B,), Tk, jnp.int32)

    from jax.experimental.pallas import tpu as pltpu
    kernel = functools.partial(
        _attn_kernel, scale=D ** -0.5, causal=causal, window=window,
        bq=bq, bk=bk, tq=Tq, tk=Tk)
    grid = (B, Hq, Tq // bq, Tk // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i, j: (b,)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q, k, v)
