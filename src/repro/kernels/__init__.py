"""Pallas TPU kernels (+ jnp oracles) for the perf-critical compute:

  ata_tag_probe   — the paper's aggregated tag array (parallel tag compare)
  flash_attention — blocked online-softmax attention (GQA/causal/window)
  wkv6            — chunked RWKV6 recurrence with data-dependent decay

Use via ``repro.kernels.ops`` which dispatches pallas / interpret / ref.
"""
from repro.kernels import ops, ref  # noqa: F401
