"""Fused probe+rank+arbitrate for the ATA round loop, as a Pallas kernel.

The paper's Fig. 6 structure is one *parallel* pass: a batch of request
tags is compared against every cluster tag array at once, the per-set
winners are selected, and the remote data port arbitrates among the
known remote hits. The simulator's lax round loop used to materialize
that as a chain of separate ops (``tagarray.probe_many`` →
``contention.group_rank`` → arbitration masks); this kernel is the
whole chain in one VMEM-resident pass per request tile:

  grid (R/BR,): each program holds BR requests plus the *complete* tag
  state (C, S, W) resident in VMEM (tags + valid + dirty of every cache
  — e.g. the paper geometry's 30x8x64 arrays are ~180KB total). Per
  tile it runs

    1. the tag selector (one-hot masked-max gather over the set axis —
       data-parallel on the VPU instead of a mux tree),
    2. the comparator group (vectorized equality over (BR, C, W)),
    3. per-set winner ranking (self-hit / first-peer selection over the
       cluster slice of the (BR, C) hit matrix), and
    4. service-port arbitration: the queue position of each winning
       remote hit at its serving cache's data port. Ranks compose
       across tiles through a VMEM scratch accumulator — the TPU grid
       is sequential, so tile *i*'s ranks start where tile *i-1*'s
       per-cache counts left off, exactly like the stable
       sort/segment-sum path of :func:`repro.core.contention.group_rank`.

The per-port *group totals* (occupancy needs them) are only known once
every tile has run; the kernel therefore emits the final per-cache
count vector as its last output (the sequential grid revisits one
block) and the wrapper gathers ``counts[src_cache]`` — one (R,) gather
outside the kernel, everything else fused.

Requests whose count does not tile by BR are padded with dead lanes
(``live=0``) that hit nothing and rank nowhere, so any R works.

``interpret=None`` auto-detects the platform: the kernel body is
interpreted off-TPU (semantics validation on CPU containers) and
compiled by Mosaic on a real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ata_tag_probe import default_interpret

DEFAULT_BR = 128   # requests per program


def _probe_rank_kernel(set_ref, qtag_ref, core_ref, cbase_ref, live_ref,
                       deny_ref, tags_ref, valid_ref, dirty_ref,
                       local_ref, way_ref, rok_ref, src_ref, rank_ref,
                       counts_ref, *, cluster_size: int):
    sets = set_ref[...]                      # (BR,) int32
    qtag = qtag_ref[...]                     # (BR,) int32
    core = core_ref[...]                     # (BR,) int32 self cache id
    cbase = cbase_ref[...]                   # (BR,) int32 first cache of cluster
    live = live_ref[...] > 0                 # (BR,) padding mask
    deny = deny_ref[...] > 0                 # (BR,) writes / prefilter hits
    tags = tags_ref[...]                     # (C, S, W) int32
    valid = valid_ref[...]                   # (C, S, W) int8
    dirty = dirty_ref[...]                   # (C, S, W) int8

    BR = sets.shape[0]
    C, S, W = tags.shape

    # the per-cache port counters carried across the sequential grid
    @pl.when(pl.program_id(0) == 0)
    def _():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    # 1. tag selector: one-hot over the set axis, masked max (int32-exact)
    onehot = sets[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (BR, S), 1)                           # (BR, S)
    sel = onehot[:, None, :, None]                       # (BR, 1, S, 1)
    g_tags = jnp.max(
        jnp.where(sel, tags[None], jnp.iinfo(jnp.int32).min),
        axis=2)                                          # (BR, C, W)
    g_valid = jnp.max(jnp.where(sel, valid[None], 0), axis=2) > 0
    g_dirty = jnp.max(jnp.where(sel, dirty[None], 0), axis=2) > 0

    # 2. comparator group: every way of every cache vs each request
    match = (g_tags == qtag[:, None, None]) & g_valid    # (BR, C, W)
    hit_c = match.any(axis=-1)                           # (BR, C)
    dirty_c = (match & g_dirty).any(axis=-1)
    way_c = jnp.argmax(match, axis=-1).astype(jnp.int32)

    # 3. per-set winner ranking over the cluster slice
    cid = jax.lax.broadcasted_iota(jnp.int32, (BR, C), 1)
    is_self = cid == core[:, None]
    in_cluster = ((cid >= cbase[:, None])
                  & (cid < cbase[:, None] + cluster_size))
    local_hit = (hit_c & is_self).any(axis=-1) & live
    # one-hot contraction == take_along_axis at the self slot
    hit_way = jnp.sum(jnp.where(is_self, way_c, 0), axis=-1)

    rmask = hit_c & in_cluster & ~is_self                # (BR, C)
    any_remote = rmask.any(axis=-1)
    # first hitting peer (lowest cache id == lowest cluster slot)
    src = jnp.min(jnp.where(rmask, cid, jnp.int32(C)), axis=-1)
    src_cache = jnp.where(any_remote, src, cbase)
    first = rmask & (cid == src_cache[:, None])
    src_dirty = (first & dirty_c).any(axis=-1)
    remote_ok = (live & ~deny & ~local_hit & any_remote & ~src_dirty)

    # 4. service-port arbitration: queue position at the serving cache's
    # data port — within-tile exclusive prefix over a one-hot key
    # matrix, offset by the counts the earlier tiles accumulated.
    oh = jnp.where(remote_ok[:, None] & (cid == src_cache[:, None]),
                   jnp.int32(1), jnp.int32(0))           # (BR, C)
    within = jnp.cumsum(oh, axis=0) - oh                 # exclusive
    carried = counts_ref[...]                            # (1, C)
    prank = jnp.sum((within + carried) * oh, axis=-1)
    counts_ref[...] = carried + jnp.sum(oh, axis=0)[None, :]

    local_ref[...] = local_hit.astype(jnp.int8)
    way_ref[...] = hit_way
    rok_ref[...] = remote_ok.astype(jnp.int8)
    src_ref[...] = src_cache
    rank_ref[...] = prank


@functools.partial(jax.jit,
                   static_argnames=("cluster_size", "br", "interpret"))
def _probe_rank_call(set_idx, qtag, core, cbase, live, deny, tags, valid,
                     dirty, *, cluster_size: int, br: int, interpret: bool):
    R = set_idx.shape[0]
    C, S, W = tags.shape
    grid = (R // br,)
    row = lambda i: (i,)          # noqa: E731 — request-tile blocks
    whole = lambda i: (0, 0, 0)   # noqa: E731 — full tag state resident
    outs = pl.pallas_call(
        functools.partial(_probe_rank_kernel, cluster_size=cluster_size),
        grid=grid,
        in_specs=[pl.BlockSpec((br,), row)] * 6
        + [pl.BlockSpec((C, S, W), whole)] * 3,
        out_specs=[pl.BlockSpec((br,), row)] * 5
        + [pl.BlockSpec((1, C), lambda i: (0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.int8),    # local_hit
            jax.ShapeDtypeStruct((R,), jnp.int32),   # hit way (self array)
            jax.ShapeDtypeStruct((R,), jnp.int8),    # remote_ok
            jax.ShapeDtypeStruct((R,), jnp.int32),   # src_cache
            jax.ShapeDtypeStruct((R,), jnp.int32),   # port rank
            jax.ShapeDtypeStruct((1, C), jnp.int32),  # final port counts
        ],
        interpret=interpret,
    )(set_idx, qtag, core, cbase, live, deny, tags, valid, dirty)
    return outs


def ata_probe_rank(set_idx, qtag, core, cluster_base, deny, tags, valid,
                   dirty, *, cluster_size: int, br: int = DEFAULT_BR,
                   interpret: bool | None = None):
    """Fused probe + per-set winner ranking + port arbitration.

    set_idx      : (R,) int32  L1 set selected by each request
    qtag         : (R,) int32  request line address (the compared tag)
    core         : (R,) int32  issuing core's cache id
    cluster_base : (R,) int32  first cache id of the issuing cluster
    deny         : (R,) bool   excluded from remote service (writes,
                               victim-prefilter hits)
    tags/valid/dirty : (C, S, W) the full aggregated tag state
    cluster_size : static aggregation breadth G

    Returns (local_hit (R,) bool, hit_way (R,) int32 — the self-array
    way, meaningful where ``local_hit`` — remote_ok (R,) bool,
    src_cache (R,) int32 — serving peer, meaningful where ``remote_ok``
    — prank (R,) int32, psize (R,) int32). ``prank``/``psize`` are the
    queue position and group size at the serving cache's data port,
    bit-identical to ``contention.group_rank(src_cache, remote_ok,
    C)``.

    R not divisible by ``br`` is padded internally with dead lanes.
    ``interpret=None`` auto-detects the platform (interpret off-TPU).
    """
    if interpret is None:
        interpret = default_interpret()
    R = set_idx.shape[0]
    C = tags.shape[0]
    br = min(br, max(R, 1))
    pad = (-R) % br
    i32 = lambda x: jnp.asarray(x, jnp.int32)       # noqa: E731
    i8 = lambda x: jnp.asarray(x, jnp.int8)         # noqa: E731
    live = jnp.ones((R,), jnp.int8)
    args = [i32(set_idx), i32(qtag), i32(core), i32(cluster_base), live,
            i8(deny)]
    if pad:
        args = [jnp.pad(a, (0, pad)) for a in args]
    local, way, rok, src, rank, counts = _probe_rank_call(
        *args, i32(tags), i8(valid), i8(dirty),
        cluster_size=cluster_size, br=br, interpret=interpret)
    if pad:
        local, way, rok, src, rank = (x[:R] for x in
                                      (local, way, rok, src, rank))
    remote_ok = rok.astype(bool)
    psize = jnp.where(remote_ok, counts[0][src], 0)
    return (local.astype(bool), way, remote_ok, src, rank, psize)
