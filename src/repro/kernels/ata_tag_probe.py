"""Aggregated-tag-array probe as a Pallas TPU kernel.

The paper's hardware structure (Fig. 6): a batch of request address tags
is compared against the tag arrays of *all* caches in a cluster in
parallel; per (request, cache) the kernel reports hit and hit-way. On a
GPU this is SRAM banks + tag selectors + comparator groups; on TPU we
re-tile it for VMEM/VPU:

  grid (R/BR, C/BC): each program holds BR requests and BC complete tag
  arrays (BC, S, W) resident in VMEM. The "tag selector" (route each
  set's tags to the comparators of the requests that selected it)
  becomes a masked-max one-hot gather over the set axis — data-parallel
  on 8x128 VPU lanes instead of a mux tree. The "comparator group" is a
  vectorized equality over (BR, BC, W).

One-hot gather (not jnp.take) keeps the int32 tag path exact and avoids
dynamic-gather lowering restrictions in Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BR = 128   # requests per program
DEFAULT_BC = 8     # tag arrays per program


def _probe_kernel(set_ref, qtag_ref, tags_ref, valid_ref,
                  hits_ref, ways_ref):
    sets = set_ref[...]                      # (BR,) int32
    qtag = qtag_ref[...]                     # (BR,) int32
    tags = tags_ref[...]                     # (BC, S, W) int32
    valid = valid_ref[...]                   # (BC, S, W) int8

    n_sets = tags.shape[1]
    # tag selector: one-hot over the set axis, masked max (exact in int32)
    onehot = sets[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (sets.shape[0], n_sets), 1)          # (BR, S)
    sel = onehot[:, None, :, None]                      # (BR, 1, S, 1)
    gathered = jnp.max(
        jnp.where(sel, tags[None], jnp.iinfo(jnp.int32).min),
        axis=2)                                          # (BR, BC, W)
    gvalid = jnp.max(jnp.where(sel, valid[None], 0), axis=2) > 0

    # comparator group: all ways of all caches vs each request in parallel
    match = (gathered == qtag[:, None, None]) & gvalid   # (BR, BC, W)
    hits_ref[...] = match.any(axis=-1).astype(jnp.int8)
    ways_ref[...] = jnp.argmax(match, axis=-1).astype(jnp.int32)


def default_interpret() -> bool:
    """Interpret off-TPU (CPU/GPU validation), compile on TPU."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("br", "bc", "interpret"))
def _ata_tag_probe_call(set_idx: jax.Array, qtag: jax.Array,
                        tags: jax.Array, valid: jax.Array, *, br: int,
                        bc: int, interpret: bool):
    R = set_idx.shape[0]
    C, S, W = tags.shape
    br = min(br, R)
    bc = min(bc, C)
    if R % br or C % bc:
        raise ValueError(f"R={R} and C={C} must tile by ({br},{bc})")
    grid = (R // br, C // bc)
    hits, ways = pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((bc, S, W), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bc, S, W), lambda i, j: (j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct((R, C), jnp.int32),
        ],
        interpret=interpret,
    )(set_idx.astype(jnp.int32), qtag.astype(jnp.int32),
      tags.astype(jnp.int32), valid.astype(jnp.int8))
    return hits.astype(bool), ways


def ata_tag_probe(set_idx: jax.Array, qtag: jax.Array, tags: jax.Array,
                  valid: jax.Array, *, br: int = DEFAULT_BR,
                  bc: int = DEFAULT_BC,
                  interpret: bool | None = None):
    """Probe R request tags against C aggregated tag arrays.

    set_idx : (R,) int32   cache set selected by each request
    qtag    : (R,) int32   request address tag
    tags    : (C, S, W) int32 tag arrays of the C caches in the cluster
    valid   : (C, S, W) bool/int8
    returns (hits (R, C) bool, ways (R, C) int32)

    ``interpret=None`` (the default) auto-detects the platform: the
    kernel body is interpreted on CPU/GPU (validation) and compiled by
    Mosaic on a real TPU. The resolution happens *here*, outside the
    jit boundary, so callers no longer hard-code an interpret mode into
    the static args.
    """
    if interpret is None:
        interpret = default_interpret()
    return _ata_tag_probe_call(set_idx, qtag, tags, valid, br=br, bc=bc,
                               interpret=interpret)
