"""Public jit'd entry points for the kernel package.

Each op dispatches between implementations:
  "pallas"    — the Pallas TPU kernel (interpret=False; real hardware)
  "interpret" — the same kernel body interpreted on CPU (validation)
  "ref"       — the pure-jnp oracle (always available, used for dry-run
                lowering and as the XLA fast path on non-TPU backends)

Models call these ops; the per-arch config picks the implementation so
the dry-run lowers pure-XLA while TPU deployments take the kernels.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.ata_probe_rank import ata_probe_rank as _probe_rank_kernel
from repro.kernels.ata_tag_probe import ata_tag_probe as _probe_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.wkv6 import wkv6 as _wkv6_kernel

IMPLS = ("ref", "interpret", "pallas")


def ata_probe(set_idx, qtag, tags, valid, *, impl: str = "ref", **kw):
    if impl == "ref":
        return _ref.ata_tag_probe_ref(set_idx, qtag, tags, valid)
    return _probe_kernel(set_idx, qtag, tags, valid,
                         interpret=(impl == "interpret"), **kw)


def ata_probe_rank(set_idx, qtag, core, cluster_base, deny, tags, valid,
                   dirty, *, cluster_size: int, impl: str = "ref", **kw):
    """Fused probe + winner pick + remote-port arbitration (one pass)."""
    if impl == "ref":
        return _ref.ata_probe_rank_ref(set_idx, qtag, core, cluster_base,
                                       deny, tags, valid, dirty,
                                       cluster_size=cluster_size)
    return _probe_rank_kernel(set_idx, qtag, core, cluster_base, deny,
                              tags, valid, dirty,
                              cluster_size=cluster_size,
                              interpret=(impl == "interpret"), **kw)


def attention(q, k, v, kv_len=None, *, causal=True, window=None,
              impl: str = "ref", **kw):
    if impl == "ref":
        if kv_len is not None:
            # fold valid-length into a window-style mask via ref path
            return _ref.attention_len_ref(q, k, v, kv_len, causal=causal,
                                          window=window)
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    return _flash_kernel(q, k, v, kv_len, causal=causal, window=window,
                         interpret=(impl == "interpret"), **kw)


def wkv6(r, k, v, w, u, initial_state=None, *, impl: str = "ref", **kw):
    if impl == "ref":
        return _ref.wkv6_ref(r, k, v, w, u, initial_state=initial_state)
    return _wkv6_kernel(r, k, v, w, u, initial_state,
                        interpret=(impl == "interpret"), **kw)
