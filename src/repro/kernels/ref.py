"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth: simple, unblocked, obviously
correct. Kernel tests sweep shapes/dtypes and assert allclose vs these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# aggregated tag-array probe (paper Fig. 6)
# --------------------------------------------------------------------------
def ata_tag_probe_ref(set_idx, qtag, tags, valid):
    """set_idx (R,), qtag (R,), tags (C,S,W), valid (C,S,W) -> hits, ways."""
    sel_tags = tags[:, set_idx, :]              # (C, R, W)
    sel_valid = valid[:, set_idx, :].astype(bool)
    match = (sel_tags == qtag[None, :, None]) & sel_valid
    hits = match.any(axis=-1).T                 # (R, C)
    ways = jnp.argmax(match, axis=-1).T.astype(jnp.int32)
    return hits, ways


def ata_probe_rank_ref(set_idx, qtag, core, cluster_base, deny, tags,
                       valid, dirty, *, cluster_size: int):
    """Fused probe + winner pick + port arbitration, unblocked.

    Mirrors ``repro.kernels.ata_probe_rank.ata_probe_rank``: per
    request, compare against every cache's selected set, report the
    self-array hit, pick the first (lowest-id) hitting cluster peer,
    and rank the serviceable remote hits at their serving caches' data
    ports in request order. Returns
    (local_hit, hit_way, remote_ok, src_cache, prank, psize), all (R,).
    """
    C = tags.shape[0]
    sel_tags = tags[:, set_idx, :]              # (C, R, W)
    sel_valid = valid[:, set_idx, :].astype(bool)
    sel_dirty = dirty[:, set_idx, :].astype(bool)
    match = (sel_tags == qtag[None, :, None]) & sel_valid
    hit_c = match.any(axis=-1).T                # (R, C)
    dirty_c = (match & sel_dirty).any(axis=-1).T
    way_c = jnp.argmax(match, axis=-1).T.astype(jnp.int32)

    cid = jnp.arange(C, dtype=jnp.int32)[None, :]
    is_self = cid == core[:, None]
    in_cluster = ((cid >= cluster_base[:, None])
                  & (cid < cluster_base[:, None] + cluster_size))
    local_hit = (hit_c & is_self).any(axis=-1)
    hit_way = jnp.take_along_axis(way_c, core[:, None], axis=1)[:, 0]

    rmask = hit_c & in_cluster & ~is_self
    any_remote = rmask.any(axis=-1)
    src = jnp.min(jnp.where(rmask, cid, jnp.int32(C)), axis=-1)
    src_cache = jnp.where(any_remote, src, cluster_base).astype(jnp.int32)
    first = rmask & (cid == src_cache[:, None])
    src_dirty = (first & dirty_c).any(axis=-1)
    remote_ok = ((~deny.astype(bool)) & ~local_hit & any_remote
                 & ~src_dirty)

    oh = (remote_ok[:, None] & (cid == src_cache[:, None])
          ).astype(jnp.int32)                   # (R, C)
    before = jnp.cumsum(oh, axis=0) - oh        # exclusive, request order
    prank = jnp.sum(before * oh, axis=-1)
    counts = jnp.sum(oh, axis=0)
    psize = jnp.where(remote_ok, counts[src_cache], 0)
    return (local_hit, hit_way, remote_ok, src_cache,
            prank.astype(jnp.int32), psize.astype(jnp.int32))


# --------------------------------------------------------------------------
# blocked causal / local attention with GQA
# --------------------------------------------------------------------------
def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None):
    """q (B, Hq, Tq, D), k/v (B, Hkv, Tk, D) -> (B, Hq, Tq, D).

    Hq must be a multiple of Hkv (GQA). ``window`` = sliding local window
    size (tokens attend to the last ``window`` positions, inclusive).
    For decode, pass Tq=1 with full-length k/v (causal=False + explicit
    lengths handled by the caller's mask).
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = (scale if scale is not None else D ** -0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * s
    Tk = k.shape[2]
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)      # align ends
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    out = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, axis=-1), vv)
    return out.astype(q.dtype)


def attention_len_ref(q, k, v, kv_len, *, causal=False, window=None,
                      scale=None):
    """attention_ref with a per-batch valid KV length (decode path)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = (scale if scale is not None else D ** -0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * s
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.broadcast_to(kpos < kv_len[:, None, None, None],
                            (B, 1, Tq, Tk))
    if causal:
        mask &= (kpos <= qpos)[None, None]
    if window is not None:
        mask &= (kpos > qpos - window)[None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)


# --------------------------------------------------------------------------
# RWKV6 (Finch) recurrence with data-dependent decay
# --------------------------------------------------------------------------
def wkv6_ref(r, k, v, w, u, *, initial_state=None):
    """Sequential oracle for the WKV6 recurrence.

    r,k,w : (B, H, T, K); v : (B, H, T, V); u : (H, K)
    w is the per-step *log* decay (<= 0); decay factor = exp(w).
    S_t = diag(exp(w_t)) S_{t-1} + k_t^T v_t
    o_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    Returns (o (B,H,T,V), final_state (B,H,K,V)).
    """
    B, H, T, K = r.shape
    V = v.shape[-1]
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    S0 = (jnp.zeros((B, H, K, V), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(S, xs):
        rt, kt, vt, wt = xs                       # (B,H,K),(B,H,K),(B,H,V)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        ot = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = jnp.exp(wt)[..., None] * S + kv
        return S, ot

    xs = (r.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), w.transpose(2, 0, 1, 3))
    S, o = jax.lax.scan(step, S0, xs)
    return o.transpose(1, 2, 0, 3), S
