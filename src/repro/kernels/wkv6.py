"""RWKV6 (Finch) WKV recurrence as a chunked Pallas TPU kernel.

The per-token recurrence (data-dependent diagonal decay)

    S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

is O(T) sequential. The kernel processes chunks of L tokens: the grid is
(B, H, T/L) with the chunk axis innermost; the (K, V) state lives in a
VMEM scratch that persists across the sequential chunk sweep. Per chunk
(c = cumulative log-decay, c_prev = c shifted):

    inter:  o  += (r * exp(c_prev)) @ S                (MXU, L x K x V)
    intra:  A[t,s] = sum_k r[t,k] k[s,k] e^{c_prev[t,k]-c[s,k]}, s < t
            o  += A @ v                                 (MXU)
    bonus:  o_t += (r_t . u . k_t) v_t
    state:  S'  = exp(c_L) * S + (k * exp(c_L - c))^T @ v

All exponents are masked *before* exponentiation so every exp argument
is <= 0 — numerically stable for arbitrarily strong decay, with no
renormalization pass. The (L, L, K) intra tensor bounds VMEM: with
L = K = 64 it is 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _wkv6_kernel(u_ref, s0_ref, r_ref, k_ref, v_ref, w_ref,
                 o_ref, sf_ref, s_scr, *, chunk):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    L = chunk
    r = r_ref[0, 0].astype(jnp.float32)          # (L, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)          # (L, V)
    w = w_ref[0, 0].astype(jnp.float32)          # (L, K), log decay <= 0
    u = u_ref[0].astype(jnp.float32)             # (K,)

    c = jnp.cumsum(w, axis=0)                    # c_t   (inclusive)
    c_prev = c - w                               # c_{t-1}
    S = s_scr[...]                               # (K, V)

    o = jax.lax.dot(r * jnp.exp(c_prev), S)      # inter-chunk  (L, V)

    # intra-chunk: strict-lower-triangular attention-like term
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    mask = (t_idx > s_idx)[:, :, None]           # (L, L, 1)
    expo = jnp.where(mask, c_prev[:, None, :] - c[None, :, :], NEG_INF)
    A = (r[:, None, :] * k[None, :, :] * jnp.exp(expo)).sum(-1)  # (L, L)
    o = o + jax.lax.dot(A, v)

    bonus = (r * u[None, :] * k).sum(-1, keepdims=True)          # (L, 1)
    o = o + bonus * v
    o_ref[0, 0] = o.astype(o_ref.dtype)

    c_last = c[-1]                               # (K,)
    S_new = (jnp.exp(c_last)[:, None] * S
             + jax.lax.dot((k * jnp.exp(c_last[None, :] - c)).T, v))
    s_scr[...] = S_new

    @pl.when(ci == nc - 1)
    def _final():
        sf_ref[0, 0] = S_new.astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, initial_state=None, *, chunk: int = 64,
         interpret: bool = True):
    """Chunked WKV6. r,k,w (B,H,T,K); v (B,H,T,V); u (H,K).

    Returns (o (B,H,T,V) in r.dtype, final_state (B,H,K,V) f32).
    """
    B, H, T, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError(f"T={T} must be a multiple of chunk={chunk}")
    if initial_state is None:
        initial_state = jnp.zeros((B, H, K, V), jnp.float32)
    grid = (B, H, T // chunk)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    o, sf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K), lambda b, h, i: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, i: (b, h, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(u, initial_state, r, k, v, w)
    return o, sf
