"""Serve a smoke model with batched requests through the ATA prefix
cache, comparing all four sharing policies end to end (real model KV
payloads, real decode). Reproduces the paper's Table-I landscape in the
serving domain: ATA = sharing hit-rate of remote/decoupled with zero
probe traffic and mostly-local service.

Run:  PYTHONPATH=src python examples/serve_ata.py
"""
import subprocess
import sys

for policy in ("private", "remote", "decoupled", "ata"):
    subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-0.6b",
         "--smoke", "--requests", "12", "--decode-steps", "4",
         "--policy", policy],
        check=True)
