"""End-to-end LM training driver: ~100M-param qwen3-family model with
checkpoint/restart (kill it mid-run and rerun: it resumes), straggler
watchdog, deterministic data. Default flags are sized for this 1-core
CPU container; pass --full for the 100M/300-step configuration.

Run:  PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse
import dataclasses

from repro.models.config import ModelConfig
from repro.launch.train import train


def lm_100m():
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=5, head_dim=64, d_ff=2560,
        vocab_size=32768, qk_norm=True, dtype="float32",
        remat="none", attn_chunk=128)


def lm_10m():
    return ModelConfig(
        name="lm-10m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, head_dim=64, d_ff=1024,
        vocab_size=8192, qk_norm=True, dtype="float32",
        remat="none", attn_chunk=128)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_lm")
    args = ap.parse_args()
    cfg = lm_100m() if args.full else lm_10m()
    steps = args.steps or (300 if args.full else 60)
    n = cfg.param_count() / 1e6
    print(f"[train_lm] {cfg.name}: {n:.0f}M params, {steps} steps")
    _, losses = train(cfg, steps=steps, global_batch=4,
                      seq_len=256 if args.full else 128,
                      ckpt_dir=args.ckpt_dir, ckpt_every=25)
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss must decrease"
