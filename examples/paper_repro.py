"""Reproduce the paper's headline results (Figs. 8-10) end to end.

Runs the four cache architectures over the ten calibrated workloads and
prints normalized IPC + L1 latency vs the paper's claims:
  +12.0% IPC on high-locality apps, no impairment on low-locality,
  decoupled-sharing +67.2% L1 latency vs ATA +6.0%.

Each (app, arch) sweeps all its kernels through ``simulate_batch`` —
one compiled call per trace shape instead of one jit trace per kernel.

Run:  PYTHONPATH=src python examples/paper_repro.py [--kernels N]
"""
import argparse
import numpy as np

from repro.core import (APPS, HIGH_LOCALITY, LOW_LOCALITY, geomean,
                        normalized_ipc, run_suite)

ap = argparse.ArgumentParser()
ap.add_argument("--kernels", type=int, default=0,
                help="kernels per app (0 = all, per Fig. 9)")
args = ap.parse_args()

suite = run_suite(kernels_per_app=args.kernels or None)
ipc = normalized_ipc(suite)
print(f"{'app':10s} {'class':5s} {'ATA':>7s} {'decoupled':>10s} {'remote':>7s}")
for app in list(HIGH_LOCALITY) + list(LOW_LOCALITY):
    cls = "HI" if APPS[app].high_locality else "LO"
    print(f"{app:10s} {cls:5s} {ipc[app]['ata']:7.3f} "
          f"{ipc[app]['decoupled']:10.3f} {ipc[app]['remote']:7.3f}")
hi = geomean([ipc[a]["ata"] for a in HIGH_LOCALITY])
lo = geomean([ipc[a]["ata"] for a in LOW_LOCALITY])
lat_d = np.mean([suite[a]["decoupled"].l1_latency
                 / suite[a]["private"].l1_latency for a in APPS])
lat_a = np.mean([suite[a]["ata"].l1_latency
                 / suite[a]["private"].l1_latency for a in APPS])
print(f"\nATA IPC gain, high-locality: {100*(hi-1):+.1f}%  (paper +12.0%)")
print(f"ATA IPC gain, low-locality : {100*(lo-1):+.1f}%  (paper: no loss)")
print(f"L1 latency: decoupled {100*(lat_d-1):+.1f}% (paper +67.2%), "
      f"ATA {100*(lat_a-1):+.1f}% (paper +6.0%)")
