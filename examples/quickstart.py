"""Quickstart: the three layers of this framework in one minute.

  1. paper core   — simulate ATA-Cache vs private L1 on one workload
  2. kernels      — the aggregated-tag-array probe as a Pallas kernel
  3. training     — a tiny LM trained for a handful of steps

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax, jax.numpy as jnp

# 1. paper core --------------------------------------------------------------
from repro.core import APPS, make_trace, registered_archs, simulate

trace = make_trace(APPS["b+tree"], kernel=0)
# the registry (repro.core.arch) holds the paper's four architectures
# plus extension variants like "ata_bypass"/"ata_fifo"
print(f"[sim] registered architectures: {registered_archs()}")
for arch in ("private", "ata", "ata_bypass"):
    r = simulate(arch, trace)
    print(f"[sim] {arch:10s} IPC={r.ipc:6.2f} l1_hit={r.l1_hit_rate:.2f} "
          f"remote_hit={r.remote_hit_rate:.2f}")

# sweeps batch: all kernels of an app in one vmapped, jitted call
from repro.core import simulate_batch

kernel_traces = [make_trace(APPS["b+tree"], kernel=k) for k in range(2)]
for k, r in enumerate(simulate_batch("ata", kernel_traces)):
    print(f"[sim] batched kernel {k}: IPC={r.ipc:6.2f}")

# 2. the aggregated tag array as a TPU kernel --------------------------------
from repro.kernels import ops

rng = np.random.default_rng(0)
C, S, W, R = 8, 8, 16, 128
tags = jnp.asarray(rng.integers(0, 1000, (C, S, W)), jnp.int32)
valid = jnp.asarray(rng.random((C, S, W)) < 0.5)
qtag = jnp.asarray(rng.integers(0, 1000, R), jnp.int32)
set_idx = jnp.asarray(rng.integers(0, S, R), jnp.int32)
hits, ways = ops.ata_probe(set_idx, qtag, tags, valid, impl="interpret")
print(f"[kernel] ata_tag_probe: {int(hits.sum())} hits across "
      f"{R} requests x {C} tag arrays (parallel compare, zero probes)")

# 3. tiny LM training ---------------------------------------------------------
from repro.configs import get_smoke_config
from repro.launch.train import train

cfg = get_smoke_config("qwen3-0.6b")
_, losses = train(cfg, steps=20, global_batch=4, seq_len=64, log_every=5)
print(f"[train] loss {losses[0]:.3f} -> {losses[-1]:.3f} over 20 steps")
